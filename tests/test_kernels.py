"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import attention_ref, flash_attention
from repro.kernels.kmeans import assign, assign_ref, minibatch_update
from repro.kernels.tomo import (
    backproject,
    backproject_ref,
    gridrec,
    mlem,
    project,
    project_ref,
    shepp_logan,
)

# ---------------------------------------------------------------------------
# kmeans
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d,k", [(64, 4, 3), (300, 7, 5), (128, 128, 16), (97, 3, 10)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_kernel_matches_ref(n, d, k, dtype):
    key = jax.random.key(n + d + k)
    pts = jax.random.normal(key, (n, d), jnp.float32).astype(dtype)
    cen = jax.random.normal(jax.random.key(1), (k, d), jnp.float32).astype(dtype)
    l_ref, d_ref = assign_ref(pts, cen)
    l_k, d_k = assign(pts, cen, use_kernel=True, block_n=64, interpret=True)
    assert bool((l_ref == l_k).all())
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_k), rtol=2e-2, atol=2e-2)


def test_kmeans_minibatch_update_converges():
    rng = np.random.default_rng(0)
    centers = np.array([[-5.0, 0.0], [5.0, 0.0], [0.0, 5.0]])

    def batch():
        return jnp.asarray(
            centers[rng.integers(0, 3, 256)] + rng.normal(0, 0.3, (256, 2)), jnp.float32
        )

    # farthest-point (kmeans++-style) seeding avoids the two-centroids-one-
    # cluster local minimum; the test verifies the *update math* converges
    pts0 = np.asarray(batch())
    seeds = [pts0[0]]
    for _ in range(2):
        d = np.min([np.sum((pts0 - s) ** 2, axis=1) for s in seeds], axis=0)
        seeds.append(pts0[int(np.argmax(d))])
    cen = jnp.asarray(np.stack(seeds), jnp.float32)
    inertia_hist = []
    for i in range(20):
        cen, _, inertia = minibatch_update(batch(), cen, decay=0.6)
        inertia_hist.append(float(inertia) / 256)
    assert inertia_hist[-1] < inertia_hist[0] / 2
    assert inertia_hist[-1] < 2.0  # near the true within-cluster variance


# ---------------------------------------------------------------------------
# tomo
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,n_det,a", [(16, 24, 8), (32, 48, 16), (32, 32, 24)])
def test_tomo_projectors_match_ref(n, n_det, a):
    img = shepp_logan(n)
    angles = jnp.linspace(0, jnp.pi, a, endpoint=False)
    np.testing.assert_allclose(
        np.asarray(project(img, angles, n_det, use_kernel=True, interpret=True)),
        np.asarray(project_ref(img, angles, n_det)),
        atol=1e-4,
    )
    sino = project_ref(img, angles, n_det)
    np.testing.assert_allclose(
        np.asarray(backproject(sino, angles, n, use_kernel=True, interpret=True)),
        np.asarray(backproject_ref(sino, angles, n)),
        atol=1e-3,
    )


def test_tomo_projectors_are_adjoint():
    n, n_det, a = 24, 32, 12
    angles = jnp.linspace(0, jnp.pi, a, endpoint=False)
    x = jax.random.normal(jax.random.key(0), (n, n))
    y = jax.random.normal(jax.random.key(1), (a, n_det))
    lhs = jnp.vdot(project_ref(x, angles, n_det), y)
    rhs = jnp.vdot(x, backproject_ref(y, angles, n))
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=1e-4)


def test_reconstruction_quality_ordering():
    """Paper §6.4: ML-EM (iterative) reconstructs with better fidelity than
    GridRec; GridRec is the cheaper algorithm."""
    n, a = 48, 60
    img = shepp_logan(n)
    angles = jnp.linspace(0, jnp.pi, a, endpoint=False)
    sino = project_ref(img, angles, n + 16)

    def err(rec):
        return float(jnp.sqrt(jnp.mean((rec - img) ** 2)))

    e_grid = err(gridrec(sino, angles, n))
    e_mlem = err(mlem(sino, angles, n, iters=16))
    assert e_mlem < e_grid
    assert e_mlem < 0.5 * float(jnp.sqrt(jnp.mean(img**2)))


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,KV,hd", [(1, 32, 2, 1, 16), (2, 64, 4, 2, 32), (1, 48, 6, 3, 8)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel_matches_ref(B, S, H, KV, hd, causal):
    ks = jax.random.split(jax.random.key(B * S), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out_k = flash_attention(q, k, v, causal=causal, block_q=16, block_kv=16,
                            use_kernel=True, interpret=True)
    out_r = flash_attention(q, k, v, causal=causal, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel_dtypes(dtype):
    B, S, H, KV, hd = 1, 32, 2, 2, 16
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd)).astype(dtype)
    out_k = flash_attention(q, k, v, block_q=16, block_kv=16, use_kernel=True, interpret=True)
    out_r = flash_attention(q, k, v, use_kernel=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32), atol=tol
    )


def test_sharded_flash_custom_vjp_grads_match_naive():
    """The distributed train-path flash (runtime/sharded_attention.py) must
    produce exact gradients — it is used inside every train step."""
    from repro.models.attention import naive_attention
    from repro.runtime.sharded_attention import flash_attention as flash_vjp

    B, S, H, KV, hd = 2, 64, 4, 2, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    q_pos = jnp.arange(S, dtype=jnp.float32)

    def loss_flash(q, k, v):
        o = flash_vjp(q.reshape(B, S, KV, H // KV, hd), k, v, q_pos, True, 16, hd**-0.5)
        return jnp.sum(jnp.sin(o))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(naive_attention(q, k, v, causal=True)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

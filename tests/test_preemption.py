"""Checkpoint-then-kill whole-pilot preemption (docs/scheduler.md).

A checkpointing continuous stage driven to zero devices by the arbiter is
*parked* — spooled, fenced, every pilot cancelled — and the next grant
resubmits the base pilot and resumes from the pre-kill spool. The
acceptance bar is the fault-tolerance one: the preempted run produces
bit-identical firings to an undisturbed baseline (zero lost, zero
duplicated).
"""
import threading
import time

from repro.broker import BrokerCluster
from repro.broker.records import Record
from repro.core import PilotComputeService
from repro.elastic import (
    ElasticConfig,
    ElasticController,
    MetricsBus,
    PreemptionHooks,
    ThresholdHysteresisPolicy,
)
from repro.scheduler import PoolTenant, ResourceArbiter, ResourceRequest
from repro.streaming import TumblingWindow


# ---------------------------------------------------------------------------
# controller park/unpark (hooks as spies)
# ---------------------------------------------------------------------------


def test_scale_to_zero_parks_and_regrant_unparks():
    svc = PilotComputeService(devices=[0, 1, 2, 3])
    try:
        pilot = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 1,
                                  "type": "flink"})
        calls = []
        bus = MetricsBus()
        ctl = ElasticController(
            svc, pilot, bus, ThresholdHysteresisPolicy(high_lag=1e9, low_lag=-1.0),
            config=ElasticConfig(min_devices=0, cooldown=0.0),
            hooks=PreemptionHooks(
                checkpoint=lambda: calls.append("checkpoint"),
                kill=lambda: calls.append("kill"),
                resume=lambda p: calls.append("resume"),
            ),
        )
        ctl.scale_to(3)
        assert ctl.devices == 3  # base + extension

        assert ctl.scale_to(0) == 0
        assert ctl.parked
        assert calls == ["checkpoint", "kill"], \
            "park must checkpoint before it kills"
        assert svc.pool.leased_devices == 0, \
            "parking must return every device, base pilot's included"
        assert bus.value("elastic.parked") == 1.0
        # idempotent: a second zero grant on a parked stage is a no-op
        assert ctl.scale_to(0) == 0
        assert calls == ["checkpoint", "kill"]

        assert ctl.scale_to(2) == 2
        assert not ctl.parked and calls[-1] == "resume"
        assert ctl.devices == 2
        assert bus.value("elastic.parked") == 0.0
        actions = [e.action for e in ctl.events]
        assert "park" in actions and "unpark" in actions
    finally:
        svc.cancel()


def test_scale_to_zero_without_hooks_keeps_the_base_pilot():
    """The pre-existing contract: no hooks wired -> a zero grant only
    shrinks extensions; the base pilot keeps its floor."""
    svc = PilotComputeService(devices=[0, 1, 2, 3])
    try:
        pilot = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 1,
                                  "type": "flink"})
        ctl = ElasticController(
            svc, pilot, MetricsBus(), ThresholdHysteresisPolicy(high_lag=1e9, low_lag=-1.0),
            config=ElasticConfig(min_devices=0, cooldown=0.0),
        )
        ctl.scale_to(3)
        assert ctl.scale_to(0) == 1  # extensions gone, base stands
        assert not ctl.parked
        assert len(pilot.lease.devices) == 1
    finally:
        svc.cancel()


# ---------------------------------------------------------------------------
# end to end: preempted run == undisturbed baseline
# ---------------------------------------------------------------------------


N_RECORDS = 300
EXPECTED_WINDOWS = 29 * 3  # 3.0s of 0.1s windows x 3 keys (see test_faults)


def _empty_cluster():
    cluster = BrokerCluster(1)
    cluster.create_topic("t", 1)
    return cluster


def _append(cluster, i):
    cluster.append("t", 0, Record(bytes([i % 3]), None, 1000.0 + i * 0.01))


def _loaded_cluster():
    cluster = _empty_cluster()
    for i in range(N_RECORDS):
        _append(cluster, i)
    return cluster


def _stage(svc, cluster, results, **kw):
    """A checkpointing continuous stage on a real pilot, plus the
    preemption hooks the pipeline runner would build for it."""
    pilot = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 1,
                              "type": "flink"})
    stream = pilot.get_context().stream(
        cluster, "t", group="g", assigner=TumblingWindow(0.1),
        window_fn=lambda key, w, msgs: (key, w, len(msgs)),
        key_fn=lambda m: m.value[0] % 3,
        emit=lambda out: results.__setitem__((out[0], out[1]), out[2]),
        checkpoint_every=50, **kw,
    )
    holder = {"pilot": pilot}

    def kill():
        plugin = holder["pilot"].plugin
        if stream in plugin.streams:
            plugin.streams.remove(stream)
        stream.crash()

    def resume(new_pilot):
        plugin = new_pilot.plugin
        if stream not in plugin.streams:
            plugin.streams.append(stream)
        stream.recover()
        if plugin.devices:
            stream.rescale(list(plugin.devices))
        holder["pilot"] = new_pilot

    hooks = PreemptionHooks(checkpoint=lambda: stream.checkpoint(),
                            kill=kill, resume=resume)
    return pilot, stream, hooks


def _await_windows(stream, n, deadline):
    while stream.stats.fired_windows < n:
        assert time.monotonic() < deadline, (
            f"only {stream.stats.fired_windows}/{n} windows fired")
        time.sleep(0.002)


def test_preempted_stage_resumes_with_zero_lost_or_duplicated_firings():
    # baseline: same trace, never preempted
    base_svc = PilotComputeService(devices=[0])
    baseline: dict = {}
    try:
        _, stream, _ = _stage(base_svc, _loaded_cluster(), baseline)
        stream.start()
        _await_windows(stream, EXPECTED_WINDOWS, time.monotonic() + 30)
        stream.stop()
    finally:
        base_svc.cancel()
    assert len(baseline) == EXPECTED_WINDOWS

    # preempted: a higher-priority tenant takes the whole pool mid-stream,
    # then leaves; the stage parks and resumes from its checkpoint. Records
    # arrive incrementally (a live source, not a preloaded log) so windows
    # fire over real time and the preemption genuinely lands mid-stream —
    # event-time windowing makes the outputs identical either way.
    svc = PilotComputeService(devices=[0, 1])
    results: dict = {}
    try:
        bus = MetricsBus()
        arb = ResourceArbiter(svc, bus)
        cluster = _empty_cluster()

        def feed():
            for i in range(N_RECORDS):
                _append(cluster, i)
                time.sleep(0.002)

        pilot, stream, hooks = _stage(svc, cluster, results)
        ctl = ElasticController(
            svc, pilot, bus, ThresholdHysteresisPolicy(high_lag=1e9, low_lag=-1.0),
            config=ElasticConfig(min_devices=0, cooldown=0.0),
            arbiter=arb,
            request=ResourceRequest("stage", min_devices=0, priority=0,
                                    target=1),
            hooks=hooks,
        )
        stream.start()
        feeder = threading.Thread(target=feed, daemon=True)
        feeder.start()
        _await_windows(stream, 30, time.monotonic() + 30)

        hi = PoolTenant(svc)
        arb.submit(hi.request("hi", min_devices=0, priority=1))
        arb.update("hi", 2)
        arb.reconcile()
        assert ctl.parked, "losing every device must park, not wedge"
        assert ctl.devices == 0
        assert hi.devices == 2, "parking freed the devices for the preemptor"
        fired_at_park = stream.stats.fired_windows
        assert fired_at_park < EXPECTED_WINDOWS, \
            "preemption landed too late to prove anything"
        time.sleep(0.05)
        assert stream.stats.fired_windows == fired_at_park, \
            "parked stream kept firing"

        feeder.join(timeout=10)
        arb.update("hi", 0)
        arb.reconcile()
        assert not ctl.parked and ctl.devices >= 1
        assert stream.recoveries == 1
        _await_windows(stream, EXPECTED_WINDOWS, time.monotonic() + 30)
        stream.stop()
        hi.close()
    finally:
        svc.cancel()
    assert results == baseline, \
        "preempted run must match the baseline bit-for-bit"

"""Sharding rules: divisibility fallbacks, ZeRO placement, batch trimming."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import ShardingRules


class FakeMesh:
    """Duck-typed mesh exposing .shape (rules only need axis sizes)."""

    def __init__(self, shape: dict):
        self.shape = shape


def rules(shape, batch_axes=(), zero=True, kind="train"):
    return ShardingRules(mesh=FakeMesh(shape), batch_axes=batch_axes, zero=zero, kind=kind)


MESH2 = {"data": 16, "model": 16}
MESH3 = {"pod": 2, "data": 16, "model": 16}


def test_tensor_axes_shard_on_model_when_divisible():
    r = rules(MESH2)
    assert r.spec(("embed", "mlp"), (4096, 14336)) == P(None, "model")
    # non-divisible tensor dim falls back to replication
    assert r.spec((None, "mlp"), (7, 100)) == P()


def test_zero_takes_largest_free_dim():
    r = rules(MESH3)
    spec = r.spec(("layers", "embed", "qkv"), (32, 4096, 6144), is_param=True)
    # qkv -> model; embed (largest remaining, 4096 % 32 == 0) -> (pod, data)
    assert spec == P(None, ("pod", "data"), "model")


def test_zero_skips_vocab_params():
    r = rules(MESH3)
    spec = r.spec(("vocab", "embed"), (32000, 4096), is_param=True)
    assert spec == P("model")  # no ZeRO on the embedding table


def test_batch_trimming():
    r = ShardingRules.for_shape(FakeMesh(MESH3), kind="train", global_batch=256)
    assert r.batch_axes == ("pod", "data")
    r = ShardingRules.for_shape(FakeMesh(MESH3), kind="decode", global_batch=16)
    assert r.batch_axes == ("data",)  # 16 % 32 != 0 -> drop "pod"
    r = ShardingRules.for_shape(FakeMesh(MESH3), kind="decode", global_batch=1)
    assert r.batch_axes == ()


def test_cache_seq_takes_unused_batch_axes():
    r = ShardingRules.for_shape(FakeMesh(MESH3), kind="decode", global_batch=1)
    spec = r.spec(("layers", "batch", "cache_seq", None, None), (32, 1, 524288, 8, 128))
    assert spec == P(None, None, ("pod", "data", "model"))
    r2 = ShardingRules.for_shape(FakeMesh(MESH3), kind="decode", global_batch=128)
    spec2 = r2.spec(("layers", "batch", "cache_seq", None, None), (32, 128, 32768, 8, 128))
    assert spec2 == P(None, ("pod", "data"), "model")


def test_no_mesh_axis_reuse_within_spec():
    r = rules(MESH2)
    # both dims want "model": second one must not reuse it
    spec = r.spec(("vocab", "mlp"), (32000, 4096))
    assert spec == P("model")


def test_param_shardings_cover_all_archs():
    """Every param of every full-size arch gets a valid spec on both meshes."""
    from repro.configs.registry import ARCHS
    from repro.models import build_model

    for mesh_shape in (MESH2, MESH3):
        r = rules(mesh_shape)
        for name, cfg in ARCHS.items():
            model = build_model(cfg)
            axes = model.param_axes()
            structs = model.param_struct()
            flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
            flat_s = jax.tree.leaves(structs)
            for ax, st in zip(flat_a, flat_s):
                spec = r.spec(ax, st.shape, is_param=True)
                # verify divisibility of every sharded dim
                for dim, entry in zip(st.shape, tuple(spec) + (None,) * (len(st.shape) - len(spec))):
                    if entry is None:
                        continue
                    axes_t = entry if isinstance(entry, tuple) else (entry,)
                    size = int(np.prod([mesh_shape[a] for a in axes_t]))
                    assert dim % size == 0, f"{name}: {ax} {st.shape} -> {spec}"

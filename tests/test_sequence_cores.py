"""Chunked-parallel sequence cores == token-level recurrent oracles.

These equivalences are what make train/prefill (chunked) consistent with
decode (recurrent) for the SSM/hybrid families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.mamba2 import conv1d_causal, ssd_chunked, ssd_recurrent
from repro.models.rwkv6 import wkv6_chunked, wkv6_recurrent


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (48, 48), (40, 8)])
def test_wkv6_chunked_equals_recurrent(T, chunk):
    B, H, N = 2, 3, 16
    ks = jax.random.split(jax.random.key(T), 6)
    r, k, v = (jax.random.normal(ks[i], (B, H, T, N)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, T, N)) - 1.0)
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    S0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.1
    o1, s1 = wkv6_recurrent(r, k, v, w, u, S0)
    o2, s2 = wkv6_chunked(r, k, v, w, u, S0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


@given(st.integers(1, 4), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_wkv6_state_continuation(n_chunks, seed):
    """Processing T tokens at once == processing them chunk-by-chunk with the
    carried state (what decode-after-prefill relies on)."""
    B, H, T, N = 1, 2, 8 * n_chunks, 8
    ks = jax.random.split(jax.random.key(seed), 5)
    r, k, v = (jax.random.normal(ks[i], (B, H, T, N)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, T, N)))
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    S0 = jnp.zeros((B, H, N, N))
    o_full, s_full = wkv6_recurrent(r, k, v, w, u, S0)
    S = S0
    outs = []
    for c in range(n_chunks):
        sl = slice(c * 8, (c + 1) * 8)
        o, S = wkv6_chunked(r[:, :, sl], k[:, :, sl], v[:, :, sl], w[:, :, sl], u, S, chunk=8)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 2)), np.asarray(o_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(s_full), atol=1e-4)


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 64), (48, 16)])
def test_ssd_chunked_equals_recurrent(T, chunk):
    Bt, H, P, N = 2, 3, 8, 16
    ks = jax.random.split(jax.random.key(T + 1), 6)
    x = jax.random.normal(ks[0], (Bt, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (Bt, T, 1, N))
    C = jax.random.normal(ks[4], (Bt, T, 1, N))
    D = jax.random.normal(ks[5], (H,)) * 0.1
    S0 = jnp.zeros((Bt, H, P, N))
    y1, s1 = ssd_recurrent(x, dt, A, B, C, D, S0)
    y2, s2 = ssd_chunked(x, dt, A, B, C, D, S0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_conv1d_causal_state_continuation():
    B, T, Ch, K = 2, 16, 6, 4
    x = jax.random.normal(jax.random.key(0), (B, T, Ch))
    w = jax.random.normal(jax.random.key(1), (K, Ch))
    b = jnp.zeros((Ch,))
    full, state_full = conv1d_causal(x, w, b, None)
    a, st = conv1d_causal(x[:, :8], w, b, None)
    bb, st2 = conv1d_causal(x[:, 8:], w, b, st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([a, bb], 1)), np.asarray(full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(state_full), atol=1e-6)


def test_blockwise_attention_matches_naive():
    from repro.models.attention import blockwise_attention, naive_attention

    B, S, H, KV, hd = 2, 64, 4, 2, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    for causal in (True, False):
        o1 = blockwise_attention(q, k, v, causal=causal, block_q=16, block_kv=16)
        o2 = naive_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_decode_attention_matches_naive_last_row():
    from repro.models.attention import decode_attention, naive_attention

    B, S, H, KV, hd = 2, 32, 4, 2, 16
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.full((B,), S - 1, jnp.int32)
    o1 = decode_attention(q, k, v, positions=pos)
    o2 = naive_attention(q, k, v, causal=False)  # all entries valid at pos=S-1
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)

"""Property suite (hypothesis) for repro.state — docs/state.md.

The invariants that make rescale a non-event for correctness:

* key -> partition is stable, total, and respects dict-key equality
  (``3 == 3.0 == True`` land on one partition);
* a range assignment gives every partition exactly one owner, for any
  owner set;
* across ANY sequence of grow/shrink migrations, every key maps to exactly
  one live partition owner and no ``(key, window)`` buffer is lost,
  duplicated, or internally reordered;
* partition serde round-trips keys, windows, message order, values and
  counters exactly.

``tests/test_state_engine.py`` holds the always-run (no-hypothesis) mirror
of these plus the engine-level integration and race-regression tests.
"""
import math
import random

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.broker.consumer import Message
from repro.state import (
    PartitionedStateStore,
    StateMigrator,
    StatePartition,
    deserialize_partition,
    key_bytes,
    moved_partitions,
    partition_for,
    range_assignment,
    serialize_partition,
)

# keys the engines can produce: hashables incl. nested tuples
keys_st = st.one_of(
    st.none(),
    st.booleans(),
    st.integers(min_value=-(2**70), max_value=2**70),
    st.floats(allow_nan=False, allow_infinity=False),
    st.text(max_size=8),
    st.binary(max_size=8),
    st.tuples(st.integers(-5, 5), st.text(max_size=3)),
)


# -- partitioner ------------------------------------------------------------


@given(keys_st, st.integers(1, 256))
@settings(max_examples=200, deadline=None)
def test_partition_stable_and_in_range(key, n):
    p = partition_for(key, n)
    assert 0 <= p < n
    assert partition_for(key, n) == p  # deterministic


@given(st.integers(-(2**52), 2**52), st.integers(1, 256))
@settings(max_examples=100, deadline=None)
def test_equal_numeric_keys_share_partition(i, n):
    # dict-key semantics: i, float(i) and np.int64(i) are ONE dict key,
    # so they must be one partition too
    assert partition_for(i, n) == partition_for(float(i), n)
    assert partition_for(i, n) == partition_for(np.int64(i), n)


@given(keys_st, keys_st)
@settings(max_examples=200, deadline=None)
def test_key_encoding_injective_for_distinct_keys(a, b):
    # distinct dict keys must never share an encoding (else two keys could
    # be conflated after a serde round trip)
    if key_bytes(a) == key_bytes(b):
        assert a == b


# -- assignment --------------------------------------------------------------


@given(st.integers(1, 256), st.lists(st.integers(), min_size=1, max_size=24, unique=True))
@settings(max_examples=200, deadline=None)
def test_range_assignment_total_and_contiguous(n, owners):
    a = range_assignment(n, owners)
    assert sorted(a) == list(range(n))  # every partition exactly one owner
    assert set(a.values()) <= set(owners)
    # each owner's partitions form one contiguous range
    for o in set(a.values()):
        mine = sorted(p for p, v in a.items() if v == o)
        assert mine == list(range(mine[0], mine[-1] + 1))


@given(
    st.integers(1, 128),
    st.lists(st.integers(0, 30), min_size=1, max_size=12, unique=True),
    st.lists(st.integers(0, 30), min_size=1, max_size=12, unique=True),
)
@settings(max_examples=200, deadline=None)
def test_moved_partitions_is_exactly_the_diff(n, old_owners, new_owners):
    old = range_assignment(n, old_owners)
    new = range_assignment(n, new_owners)
    moved = moved_partitions(old, new)
    assert moved == sorted(p for p in range(n) if old[p] != new[p])
    assert moved_partitions(old, old) == []


@given(st.integers(2, 128), st.integers(1, 10))
@settings(max_examples=100, deadline=None)
def test_grow_by_one_moves_a_minority(n, k):
    """Contiguous ranges keep the k -> k+1 diff well under a full reshuffle
    (modulo striping would move ~(1 - 1/(k+1)) of all partitions)."""
    old = range_assignment(n, list(range(k)))
    new = range_assignment(n, list(range(k + 1)))
    moved = moved_partitions(old, new)
    # each of the k old ranges donates only its tail: <= n/(k+1) per owner
    assert len(moved) <= n * k // (k + 1)


# -- migration: no loss, no dup, single owner ----------------------------------


owner_sets_st = st.lists(
    st.lists(st.integers(0, 9), min_size=1, max_size=6, unique=True),
    min_size=1,
    max_size=6,
)


def _state_of(store):
    """Observable state: every buffer with its exact message (offset,
    timestamp) sequence — order-sensitive on purpose."""
    return {
        kw: [(m.offset, m.timestamp) for m in msgs] for kw, msgs in store.items()
    }


@given(st.lists(keys_st, min_size=1, max_size=24), owner_sets_st, st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_no_buffer_lost_or_duplicated_across_migrations(keys, owner_seq, n_partitions):
    store = PartitionedStateStore(n_partitions)
    for j, key in enumerate(keys):
        w = (float(j % 3), float(j % 3) + 1.0)
        store.append(key, w, Message(0, j, 0.25 + j, np.array([float(j)])))
    expected = _state_of(store)
    migrator = StateMigrator()
    for owners in owner_seq:
        report = migrator.migrate(store, owners)
        # 1) nothing lost, duplicated, or reordered
        assert _state_of(store) == expected
        # 2) every key has exactly one live owner, from the new owner set
        for key in keys:
            assert store.owner_of(key) in owners
        # 3) buffers live only in the partition their key hashes to
        for pid, part in store.partitions.items():
            for (k, _w) in part.buffers:
                assert partition_for(k, n_partitions) == pid
        # 4) only the assignment diff moved
        assert set(report.moved) <= set(range(n_partitions))
    migrator.cleanup()  # don't litter /tmp with per-example spools


@given(st.lists(keys_st, min_size=1, max_size=16), owner_sets_st)
@settings(max_examples=50, deadline=None)
def test_unmoved_partitions_are_untouched(keys, owner_seq):
    """Partitions whose owner did not change must not even be re-serialized
    (identity-preserved) — migration cost is the diff, not the ring."""
    store = PartitionedStateStore(32)
    for j, key in enumerate(keys):
        store.append(key, (0.0, 1.0), Message(0, j, 0.5, float(j)))
    migrator = StateMigrator()
    for owners in owner_seq:
        before = dict(store.partitions)
        old_assignment = dict(store.assignment)
        report = migrator.migrate(store, owners)
        assert list(report.moved) == moved_partitions(old_assignment, store.assignment)
        for pid in range(32):
            if pid not in report.moved:
                assert store.partitions[pid] is before[pid]
    migrator.cleanup()


# -- serde ---------------------------------------------------------------------


values_st = st.one_of(
    st.none(),
    st.booleans(),
    st.integers(-(2**40), 2**40),
    st.floats(allow_nan=False),
    st.text(max_size=8),
    st.binary(max_size=8),
    st.lists(st.floats(allow_nan=False, allow_infinity=False), max_size=4).map(
        lambda xs: np.asarray(xs, dtype=np.float64)
    ),
    st.tuples(st.integers(-5, 5), st.text(max_size=3)),
)


def _values_equal(a, b):
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (
            isinstance(a, np.ndarray)
            and isinstance(b, np.ndarray)
            and a.dtype == b.dtype
            and a.shape == b.shape
            and np.array_equal(a, b)
        )
    return type(a) is type(b) and a == b


@given(
    st.lists(
        st.tuples(keys_st, st.floats(0.0, 1e6, allow_nan=False), values_st),
        min_size=0,
        max_size=12,
    ),
    st.integers(0, 100),
)
@settings(max_examples=100, deadline=None)
def test_partition_serde_roundtrip(entries, late):
    part = StatePartition(pid=3, late_records=late)
    for j, (key, ws, value) in enumerate(entries):
        part.buffers.setdefault((key, (ws, ws + 1.0)), []).append(
            Message(0, j, ws + 0.5, value)
        )
        part.records += 1
        part.max_event_time = max(part.max_event_time, ws + 0.5)
    restored = deserialize_partition(serialize_partition(part))
    assert restored.pid == part.pid
    assert restored.records == part.records
    assert restored.late_records == part.late_records
    assert restored.max_event_time == part.max_event_time
    assert set(restored.buffers) == set(part.buffers)
    for kw, msgs in part.buffers.items():
        got = restored.buffers[kw]
        assert [(m.partition, m.offset, m.timestamp) for m in got] == [
            (m.partition, m.offset, m.timestamp) for m in msgs
        ]
        assert all(_values_equal(a.value, b.value) for a, b in zip(msgs, got))


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_migration_sequence_seeded_fuzz(seed):
    """Randomized end-to-end mirror of the invariants above, driven off one
    seed — the same generator the always-run suite uses, so a hypothesis
    failure here reproduces locally via its printed seed."""
    rnd = random.Random(seed)
    n = rnd.choice([1, 8, 32, 64])
    store = PartitionedStateStore(n)
    expected: dict = {}
    for j in range(rnd.randint(1, 40)):
        key = rnd.choice([None, j % 7, f"k{j % 5}", (j % 3, "x"), float(j % 4)])
        w = (float(j % 5), float(j % 5) + 1.0)
        store.append(key, w, Message(0, j, 0.5 + j, float(j)))
        expected.setdefault((key, w), []).append((0, j))
    snap = _state_of(store)
    migrator = StateMigrator()
    for _ in range(rnd.randint(1, 8)):
        owners = rnd.sample(range(10), rnd.randint(1, 6))
        migrator.migrate(store, owners)
        assert _state_of(store) == snap
        for (key, _w) in snap:
            assert store.owner_of(key) in owners
    migrator.cleanup()

"""Property tests for the columnar frame codec (repro.transport.frames).

Separate module from test_transport.py so the module-level importorskip
(hypothesis is a dev-only dependency) never hides the always-run transport
tests — same convention as test_state.py.

The codec's contract: ``decode_frame(pack_frame(values, ts, key))``
returns the same values (dtype, shape, content), timestamps and key for
*any* batch — mixed dtypes and shapes, structured records, Fortran-ordered
and sliced (non-contiguous) inputs, zero-length arrays, raw bytes —
regardless of how the batch interleaves its column groups.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")

from hypothesis import given, settings, strategies as st

from repro.transport import decode_frame, pack_frame

SIMPLE_DTYPES = st.sampled_from(
    ["<u1", "<u2", "<i4", "<i8", "<f4", "<f8", "<c8", "?"])

STRUCTURED_DTYPES = st.sampled_from([
    np.dtype([("id", "<u4"), ("x", "<f8")]),
    np.dtype([("id", "<u4"), ("pos", "<f8", (3,)), ("flag", "?")]),
    np.dtype([("a", "<i2"), ("b", [("c", "<f4"), ("d", "<u1")])]),
])

SHAPES = st.sampled_from([(0,), (1,), (7,), (3, 4), (2, 3, 2), (16, 16)])


@st.composite
def arrays(draw):
    if draw(st.booleans()):
        dt = np.dtype(draw(SIMPLE_DTYPES))
        shape = draw(SHAPES)
        n = int(np.prod(shape))
        raw = draw(st.binary(min_size=n * dt.itemsize, max_size=n * dt.itemsize))
        arr = np.frombuffer(raw, dtype=dt).reshape(shape).copy()
    else:
        dt = draw(STRUCTURED_DTYPES)
        n = draw(st.integers(min_value=0, max_value=8))
        arr = np.zeros(n, dtype=dt)
        if n and dt.names:
            first = dt.names[0]
            arr[first] = np.arange(n).astype(arr[first].dtype)
    # exercise non-contiguous and Fortran-ordered inputs: the encoder must
    # normalize layout without changing content
    variant = draw(st.integers(min_value=0, max_value=2))
    if variant == 1 and arr.ndim >= 2:
        arr = np.asfortranarray(arr)
    elif variant == 2 and arr.ndim >= 1 and arr.shape[0] >= 2:
        arr = arr[::2]
    return arr


def values_strategy():
    return st.lists(
        st.one_of(arrays(), st.binary(max_size=64)), min_size=0, max_size=12)


@settings(max_examples=200, deadline=None)
@given(values=values_strategy(), with_ts=st.booleans(),
       key=st.one_of(st.none(), st.binary(min_size=1, max_size=16)))
def test_frame_roundtrip_is_lossless(values, with_ts, key):
    ts = [float(i) * 0.5 for i in range(len(values))] if with_ts else None
    frame = decode_frame(pack_frame(values, ts, key=key))
    assert len(frame) == len(values)
    assert frame.timestamps == ts
    assert frame.key == key
    assert len(frame.values) == len(values)
    for got, want in zip(frame.values, values):
        if isinstance(want, bytes):
            assert got == want
        else:
            assert got.dtype == want.dtype
            assert got.shape == want.shape
            # byte-exact: random float payloads contain NaNs, which
            # np.array_equal treats as unequal
            assert np.ascontiguousarray(got).tobytes() == \
                np.ascontiguousarray(want).tobytes()


@settings(max_examples=100, deadline=None)
@given(values=st.lists(arrays(), min_size=1, max_size=8))
def test_zero_copy_decode_matches_copy_out(values):
    buf = pack_frame(values)
    zc = decode_frame(bytearray(buf), zero_copy=True)
    co = decode_frame(buf)
    for a, b in zip(zc.values, co.values):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert np.ascontiguousarray(a).tobytes() == \
            np.ascontiguousarray(b).tobytes()


@settings(max_examples=100, deadline=None)
@given(rows=st.integers(min_value=0, max_value=16),
       dt=STRUCTURED_DTYPES)
def test_structured_dtype_fields_survive_the_wire(rows, dt):
    arr = np.zeros(rows, dtype=dt)
    frame = decode_frame(pack_frame([arr, arr]))
    for got in frame.values:
        # dtype equality is field-exact: names, nested formats, subshapes
        assert got.dtype == dt
        assert np.array_equal(got, arr)

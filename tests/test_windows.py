"""Window-assigner + watermark properties (hypothesis)."""
import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.streaming import SessionWindow, SlidingWindow, TumblingWindow, WatermarkTracker

ts_strategy = st.floats(min_value=0.0, max_value=1e6, allow_nan=False, allow_infinity=False)


@given(ts_strategy, st.floats(0.1, 100.0))
@settings(max_examples=100, deadline=None)
def test_tumbling_contains_and_partitions(ts, size):
    (w,) = TumblingWindow(size).assign(ts)
    assert w[0] <= ts < w[1]
    assert math.isclose(w[1] - w[0], size)
    # window starts are aligned to the size grid
    assert math.isclose(w[0] % size, 0.0, abs_tol=1e-6) or math.isclose(w[0] % size, size, abs_tol=1e-6)


@given(ts_strategy, st.floats(1.0, 50.0), st.integers(1, 4))
@settings(max_examples=100, deadline=None)
def test_sliding_every_window_contains_ts(ts, slide, mult):
    size = slide * mult
    ws = SlidingWindow(size, slide).assign(ts)
    assert len(ws) >= 1
    for w in ws:
        assert w[0] <= ts < w[1]
        assert math.isclose(w[1] - w[0], size, rel_tol=1e-9)
    # a timestamp belongs to ~size/slide sliding windows
    assert len(ws) <= mult + 1


def test_session_windows_merge_within_gap():
    s = SessionWindow(gap=10.0)
    s.assign(0.0, key="k")
    (w,) = s.assign(5.0, key="k")  # within gap -> merged
    assert w[0] == 0.0 and w[1] == 15.0
    (w2,) = s.assign(100.0, key="k")  # new session
    assert w2[0] == 100.0
    closed = s.close_before(90.0, key="k")
    assert closed == []  # active session replaced the old one


def test_watermark_lateness():
    t = WatermarkTracker(allowed_lateness=5.0)
    t.observe(100.0)
    assert t.watermark == 95.0
    assert t.is_late(94.0)
    assert not t.is_late(96.0)


@given(st.lists(ts_strategy, min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_watermark_monotonic(times):
    t = WatermarkTracker()
    prev = -math.inf
    for ts in times:
        t.observe(ts)
        assert t.watermark >= prev
        prev = t.watermark

"""Window-assigner + watermark properties (hypothesis)."""
import math
import random

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.streaming import SessionWindow, SlidingWindow, TumblingWindow, WatermarkTracker

ts_strategy = st.floats(min_value=0.0, max_value=1e6, allow_nan=False, allow_infinity=False)


@given(ts_strategy, st.floats(0.1, 100.0))
@settings(max_examples=100, deadline=None)
def test_tumbling_contains_and_partitions(ts, size):
    (w,) = TumblingWindow(size).assign(ts)
    assert w[0] <= ts < w[1]
    assert math.isclose(w[1] - w[0], size)
    # window starts are aligned to the size grid
    assert math.isclose(w[0] % size, 0.0, abs_tol=1e-6) or math.isclose(w[0] % size, size, abs_tol=1e-6)


@given(ts_strategy, st.floats(1.0, 50.0), st.integers(1, 4))
@settings(max_examples=100, deadline=None)
def test_sliding_every_window_contains_ts(ts, slide, mult):
    size = slide * mult
    ws = SlidingWindow(size, slide).assign(ts)
    assert len(ws) >= 1
    for w in ws:
        assert w[0] <= ts < w[1]
        assert math.isclose(w[1] - w[0], size, rel_tol=1e-9)
    # a timestamp belongs to ~size/slide sliding windows
    assert len(ws) <= mult + 1


def test_session_windows_merge_within_gap():
    s = SessionWindow(gap=10.0)
    s.assign(0.0, key="k")
    (w,) = s.assign(5.0, key="k")  # within gap -> merged
    assert w[0] == 0.0 and w[1] == 15.0
    (w2,) = s.assign(100.0, key="k")  # new session; the old one stays live
    assert w2[0] == 100.0
    assert s.sessions("k") == [(0.0, 15.0), (100.0, 110.0)]
    closed = s.close_before(90.0, key="k")
    assert closed == [(0.0, 15.0)]  # watermark closes it; the new one stays
    assert s.sessions("k") == [(100.0, 110.0)]


def test_session_out_of_order_bridges_two_sessions():
    s = SessionWindow(gap=10.0)
    s.assign(0.0, key="k")
    s.assign(25.0, key="k")  # disjoint second session
    (w,) = s.assign(8.0, key="k")  # late arrival overlaps both -> one session
    assert w == (0.0, 35.0)
    assert s.sessions("k") == [(0.0, 35.0)]


def test_watermark_lateness():
    t = WatermarkTracker(allowed_lateness=5.0)
    t.observe(100.0)
    assert t.watermark == 95.0
    assert t.is_late(94.0)
    assert not t.is_late(96.0)


@given(st.lists(ts_strategy, min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_watermark_monotonic(times):
    t = WatermarkTracker()
    prev = -math.inf
    for ts in times:
        t.observe(ts)
        assert t.watermark >= prev
        prev = t.watermark


# -- coverage: every timestamp lands in >= 1 window, for every assigner ------


@given(ts_strategy, st.floats(0.1, 100.0))
@settings(max_examples=100, deadline=None)
def test_tumbling_covers_every_timestamp(ts, size):
    ws = TumblingWindow(size).assign(ts)
    assert len(ws) >= 1 and all(w[0] <= ts < w[1] for w in ws)


@given(ts_strategy, st.floats(0.1, 50.0), st.floats(0.1, 50.0))
@settings(max_examples=100, deadline=None)
def test_sliding_covers_every_timestamp(ts, slide, extra):
    # size >= slide but NOT necessarily an integer multiple — the gapless
    # guarantee must not depend on aligned geometry
    size = slide + extra
    ws = SlidingWindow(size, slide).assign(ts)
    assert len(ws) >= 1 and all(w[0] <= ts < w[1] for w in ws)


@given(ts_strategy, st.floats(0.1, 100.0))
@settings(max_examples=100, deadline=None)
def test_session_covers_every_timestamp(ts, gap):
    (w,) = SessionWindow(gap).assign(ts, key="k")
    assert w[0] <= ts < w[1]


# -- session merge: order-insensitive ---------------------------------------


@given(
    st.lists(st.floats(0.0, 1e4, allow_nan=False), min_size=1, max_size=16),
    st.floats(0.1, 50.0),
    st.integers(0, 2**32 - 1),
)
@settings(max_examples=150, deadline=None)
def test_session_merge_order_insensitive(times, gap, seed):
    """The final session set of a key is the interval union of its
    [ts, ts+gap) proto-sessions — a pure function of the SET of
    timestamps. (Rescale determinism leans on this: migrations replay
    buffers in canonical, not arrival, order.)"""
    a = SessionWindow(gap)
    for ts in times:
        a.assign(ts, key="k")
    perm = list(times)
    random.Random(seed).shuffle(perm)
    b = SessionWindow(gap)
    for ts in perm:
        b.assign(ts, key="k")
    assert a.sessions("k") == b.sessions("k")


@given(
    st.lists(st.floats(0.0, 1e4, allow_nan=False), min_size=1, max_size=16),
    st.floats(0.1, 50.0),
    st.floats(0.0, 1.2e4, allow_nan=False),
)
@settings(max_examples=100, deadline=None)
def test_session_close_before_partitions_sessions(times, gap, wm):
    s = SessionWindow(gap)
    for ts in times:
        s.assign(ts, key="k")
    before = s.sessions("k")
    closed = s.close_before(wm, key="k")
    assert all(e <= wm for (_, e) in closed)
    assert all(e > wm for (_, e) in s.sessions("k"))
    assert sorted(closed + s.sessions("k")) == before  # nothing lost


# -- allowed lateness: the boundary is exact, not off-by-one -----------------


@given(st.integers(0, 10**9), st.integers(0, 10**6))
@settings(max_examples=100, deadline=None)
def test_allowed_lateness_boundary_exact(T, lateness):
    """Integer-valued floats subtract exactly, so the boundary record at
    ts == watermark must be accepted and the previous float rejected —
    an off-by-one (``<=`` vs ``<``) fails one of these two."""
    t = WatermarkTracker(allowed_lateness=float(lateness))
    t.observe(float(T))
    wm = float(T - lateness)
    assert t.watermark == wm
    assert not t.is_late(wm)  # exactly-at-watermark is NOT late
    assert t.is_late(math.nextafter(wm, -math.inf))  # one ulp earlier is


@given(st.floats(-1e9, 1e9, allow_nan=False))
@settings(max_examples=100, deadline=None)
def test_zero_lateness_rejects_nothing_at_watermark(ts):
    t = WatermarkTracker()
    t.observe(ts)
    assert not t.is_late(ts)  # a re-delivery of the max-ts record is on time
    assert t.is_late(math.nextafter(ts, -math.inf))

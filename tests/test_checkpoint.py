"""Checkpoint manager: roundtrips, atomicity, retention, async, offsets."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b16": jnp.ones((5,), jnp.bfloat16) * 1.5},
        "opt": {"step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(3, state, meta={"offsets": {"0": 42}})
    restored, meta = mgr.restore(state)
    assert meta["offsets"] == {"0": 42}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_keep_last_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in range(5):
        mgr.save(s, _state())
    assert mgr.steps() == [3, 4]


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=10)
    s = _state()
    for step in (1, 2):
        s2 = jax.tree.map(lambda x: x * step if x.dtype != jnp.int32 else x, s)
        mgr.save(step, s2)
    r1, _ = mgr.restore(s, step=1)
    r2, _ = mgr.restore(s, step=2)
    np.testing.assert_array_equal(np.asarray(r2["params"]["w"]), 2 * np.asarray(r1["params"]["w"]))


def test_async_save_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(1, _state())
    mgr.wait()
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(_state())
    assert restored["opt"]["step"] == 7


def test_tmp_dirs_invisible(tmp_path):
    """A crash mid-write must not surface a partial checkpoint."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_0000000009.tmp"))
    assert mgr.steps() == []
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state())


def test_missing_leaf_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        mgr.restore({"a": jnp.zeros(3), "b": jnp.zeros(2)})

"""Per-arch smoke tests (reduced configs) + decode/prefill consistency.

Every assigned architecture instantiates a REDUCED same-family config and
runs forward + one train step on CPU, asserting output shapes and finite
values. Decode-vs-prefill equality is the strong correctness check for the
KV-cache / state machinery of every family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, get_arch
from repro.models import build_model
from repro.runtime.optimizer import Optimizer, OptimizerConfig

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, model, B=2, S=32, seed=0):
    key = jax.random.key(seed)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, dtype=jnp.int32)
    if cfg.family == "encdec":
        return {
            "frame_embeds": jax.random.normal(key, (B, S // 2, cfg.d_model), jnp.float32),
            "tokens": toks[:, : S // 2],
        }
    if cfg.family == "vlm":
        P = cfg.n_patches
        return {
            "tokens": toks[:, : S - P],
            "patch_embeds": jax.random.normal(key, (B, P, cfg.d_model), jnp.float32),
        }
    return {"tokens": toks}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, model)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert float(loss) > 0

    opt = Optimizer(OptimizerConfig(name="adamw", learning_rate=1e-3, warmup_steps=1))
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        p2, s2, stats = opt.update(g, state, params)
        return p2, s2, l

    p2, s2, l1 = step(params, state, batch)
    _, _, l2 = step(p2, s2, batch)
    assert bool(jnp.isfinite(l2))
    assert float(l2) < float(l1), f"{arch}: loss should drop after an sgd-ish step"
    # shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_prefill(arch):
    """Prefill on S tokens == prefill on S-1 then decode of token S-1."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B = 2
    S = 32 if cfg.family == "vlm" else 16  # vlm: leave room past the patches
    batch = make_batch(cfg, model, B=B, S=S, seed=3)

    lg_full, _ = jax.jit(model.prefill)(params, batch)

    if cfg.family == "encdec":
        short = {"frame_embeds": batch["frame_embeds"], "tokens": batch["tokens"][:, :-1]}
        pos_val = batch["tokens"].shape[1] - 1
        last_tok = batch["tokens"][:, -1:]
    elif cfg.family == "vlm":
        short = {"tokens": batch["tokens"][:, :-1], "patch_embeds": batch["patch_embeds"]}
        pos_val = cfg.n_patches + batch["tokens"].shape[1] - 1
        last_tok = batch["tokens"][:, -1:]
    else:
        short = {"tokens": batch["tokens"][:, :-1]}
        pos_val = S - 1
        last_tok = batch["tokens"][:, -1:]

    _, cache = jax.jit(model.prefill)(params, short)

    # grow only the *self-attention* KV caches ("k"/"v") by one slot;
    # ssm/shift states and cross-attn memory are size-invariant
    def grow_kv(c):
        pad = [(0, 0)] * c.ndim
        pad[2] = (0, 1)
        return jnp.pad(c, pad)

    if isinstance(cache, dict) and "k" in cache:
        cache = dict(cache, k=grow_kv(cache["k"]), v=grow_kv(cache["v"]))
    dec = {"tokens": last_tok, "positions": jnp.full((B,), pos_val, jnp.int32)}
    lg_dec, _ = jax.jit(model.decode)(params, cache, dec)
    np.testing.assert_allclose(
        np.asarray(lg_full, np.float32), np.asarray(lg_dec, np.float32), atol=2e-4, rtol=2e-3
    )


def test_param_counts_match_published_scale():
    """Full configs should land near their advertised parameter counts."""
    expect = {
        "smollm-135m": (0.10e9, 0.20e9),
        "stablelm-1.6b": (1.2e9, 2.2e9),
        "starcoder2-3b": (2.5e9, 3.8e9),
        "rwkv6-3b": (2.2e9, 3.6e9),
        "qwen3-14b": (12e9, 16e9),
        "zamba2-1.2b": (0.9e9, 1.7e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
        "llava-next-mistral-7b": (6.5e9, 8.0e9),
        "seamless-m4t-medium": (0.5e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_arch(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} params not in [{lo:.1e}, {hi:.1e}]"


def test_moe_active_params_smaller_than_total():
    cfg = get_arch("phi3.5-moe-42b-a6.6b")
    total, active = cfg.param_count(), cfg.active_param_count()
    assert active < total / 3
    assert 4e9 < active < 9e9  # ~6.6B advertised

"""Fault-tolerance units: schedule DSL, injector, broker retry/timeout/
shedding, supervisor restart backoff, inline crash/recover.

The end-to-end seeded chaos matrix (fault runs bit-identical to a
fault-free baseline) lives in tests/test_chaos_faults.py; this module
pins the individual mechanisms it composes.
"""
import threading
import time

import pytest

from repro.broker import (
    BrokerCluster,
    BrokerTimeout,
    BrokerUnavailable,
    Consumer,
    ConsumerGroup,
    Producer,
)
from repro.core import PilotComputeService
from repro.engines.continuous import ContinuousStream
from repro.faults import KINDS, FaultInjector, FaultSchedule, FaultSpec
from repro.streaming import TumblingWindow
from repro.workers.supervisor import WorkerSupervisor


# ---------------------------------------------------------------------------
# schedule DSL
# ---------------------------------------------------------------------------


def test_schedule_parse_text_form():
    sched = FaultSchedule.parse(
        """
        # leader election mid-stream
        kill_broker_node @records=500 node=leader blackout=0.2
        kill_pilot       @records=900 ; slow_consumer @watermark=1003.5 delay=0.01 until_records=1200
        """
    )
    assert len(sched) == 3
    kb, kp, sc = list(sched)
    assert kb.kind == "kill_broker_node"
    assert kb.at_records == 500
    assert kb.params == {"node": "leader", "blackout": 0.2}
    assert kp.kind == "kill_pilot" and kp.at_records == 900 and kp.params == {}
    assert sc.at_watermark == 1003.5
    assert sc.params == {"delay": 0.01, "until_records": 1200}


def test_schedule_fluent_matches_parsed():
    parsed = FaultSchedule.parse("delay_io @records=10 delay=0.005 until_records=20")
    built = FaultSchedule().delay_io(at_records=10, delay=0.005, until_records=20)
    assert list(parsed) == list(built)


def test_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("explode", at_records=1)
    with pytest.raises(ValueError, match="exactly one"):
        FaultSpec("kill_pilot")  # no trigger
    with pytest.raises(ValueError, match="exactly one"):
        FaultSpec("kill_pilot", at_records=1, at_watermark=2.0)
    with pytest.raises(ValueError, match="cannot parse token"):
        FaultSchedule.parse("kill_pilot @records=1 garbage")
    assert set(KINDS) >= {"kill_broker_node", "kill_pilot", "slow_consumer"}


def test_spec_due_and_trigger():
    by_rec = FaultSpec("kill_pilot", at_records=100)
    assert not by_rec.due(99, float("inf"))
    assert by_rec.due(100, float("-inf"))
    assert by_rec.trigger == "records>=100"
    by_wm = FaultSpec("kill_pilot", at_watermark=5.0)
    assert not by_wm.due(10**9, 4.9)
    assert by_wm.due(0, 5.0)
    assert by_wm.trigger == "watermark>=5.0"


# ---------------------------------------------------------------------------
# injector
# ---------------------------------------------------------------------------


def test_injector_fires_once_and_reverts_timed_faults():
    cluster = BrokerCluster(1)
    records = [0]
    sched = FaultSchedule().delay_io(at_records=10, delay=0.003, until_records=20)
    inj = FaultInjector(sched, cluster=cluster, records_fn=lambda: records[0],
                        watermark_fn=lambda: float("-inf")).start()
    time.sleep(0.02)
    assert cluster.io_delay == 0.0 and inj.fired == 0
    records[0] = 10
    deadline = time.monotonic() + 2
    while cluster.io_delay == 0.0 and time.monotonic() < deadline:
        time.sleep(0.002)
    assert cluster.io_delay == pytest.approx(0.003)
    records[0] = 25  # past until_records -> revert
    assert inj.wait(2.0), "injector never drained its schedule"
    assert cluster.io_delay == 0.0
    assert inj.fired == 1
    kinds = [(e.kind, e.detail) for e in inj.events]
    assert kinds[0][0] == "delay_io" and "io delay" in kinds[0][1]
    assert kinds[1] == ("delay_io", "reverted")
    inj.stop()


def test_injector_action_override_and_failure_capture():
    seen = []
    sched = (FaultSchedule()
             .kill_pilot(at_records=1)
             .drop_heartbeats(at_records=1))
    inj = FaultInjector(
        sched, records_fn=lambda: 5, watermark_fn=lambda: 0.0,
        actions={"kill_pilot": lambda injector, spec: seen.append(spec.kind) or "custom"},
    ).start()
    assert inj.wait(2.0)
    inj.stop()
    assert seen == ["kill_pilot"]
    by_kind = {e.kind: e.detail for e in inj.events}
    assert by_kind["kill_pilot"] == "custom"
    # drop_heartbeats has no service bound -> the action raises, the poller
    # survives and records the failure instead of dying silently
    assert by_kind["drop_heartbeats"].startswith("action failed:")


def test_injector_picks_partition_leader():
    cluster = BrokerCluster(3)
    cluster.create_topic("t", 1, replication_factor=2)
    inj = FaultInjector(FaultSchedule(), cluster=cluster, topic="t")
    spec = FaultSpec("kill_broker_node", at_records=1, params={"node": "leader"})
    assert inj._pick_node(spec) == cluster.topic("t").leaders[0]
    spec = FaultSpec("kill_broker_node", at_records=1, params={"node": 2})
    assert inj._pick_node(spec) == 2


def test_injector_slow_consumer_sets_and_expires_poll_delay():
    cluster = BrokerCluster(1)
    cluster.create_topic("t", 1)
    c = Consumer(cluster, ConsumerGroup(cluster, "g", "t"), "m")
    records = [50]
    sched = FaultSchedule().slow_consumer(at_records=10, delay=0.004, until_records=100)
    inj = FaultInjector(sched, cluster=cluster, consumer=c,
                        records_fn=lambda: records[0],
                        watermark_fn=lambda: float("-inf")).start()
    deadline = time.monotonic() + 2
    while c.injected_poll_delay == 0.0 and time.monotonic() < deadline:
        time.sleep(0.002)
    assert c.injected_poll_delay == pytest.approx(0.004)
    records[0] = 120
    assert inj.wait(2.0)
    assert c.injected_poll_delay == 0.0
    inj.stop()


# ---------------------------------------------------------------------------
# broker: replication failover, retry, typed timeouts, shedding
# ---------------------------------------------------------------------------


def test_producer_send_timeout_raises_typed_error_on_stalled_bucket():
    # 40 B/s budget vs a ~200 B record: the token bucket can never clear it
    # inside the deadline, so send must fail fast instead of hanging
    cluster = BrokerCluster(1, io_rate_per_node=40.0)
    cluster.create_topic("t", 1)
    prod = Producer(cluster, "t", serializer="raw", send_timeout=0.15)
    t0 = time.monotonic()
    with pytest.raises(BrokerTimeout):
        prod.send(b"x" * 200)
    assert time.monotonic() - t0 < 2.0


def test_producer_retries_through_failover_blackout():
    cluster = BrokerCluster(3)
    cluster.create_topic("t", 1, replication_factor=2)
    prod = Producer(cluster, "t", serializer="raw", seed=1)
    for i in range(50):
        prod.send(bytes([i]))
    cluster.fail_node(cluster.topic("t").leaders[0], blackout=0.15)
    # the send lands on the promoted leader after riding out the election
    assert prod.send(b"after") == 50
    assert prod.retries >= 1
    assert cluster.failovers >= 1
    assert cluster.lost_records == 0
    recs = cluster.read("t", 0, 0, 1000)
    assert len(recs) == 51  # every acked record survived the node loss


def test_producer_retry_exhaustion_raises_broker_timeout():
    cluster = BrokerCluster(3)
    cluster.create_topic("t", 1, replication_factor=2)
    prod = Producer(cluster, "t", serializer="raw", retry_timeout=0.2, seed=1)
    prod.send(b"x")
    cluster.fail_node(cluster.topic("t").leaders[0], blackout=5.0)
    t0 = time.monotonic()
    with pytest.raises(BrokerTimeout):
        prod.send(b"y")
    assert 0.1 < time.monotonic() - t0 < 2.0
    assert prod.retries >= 2  # backed off and reattempted before giving up


def test_consumer_poll_treats_blackout_as_empty():
    cluster = BrokerCluster(2)
    cluster.create_topic("t", 1, replication_factor=2)
    prod = Producer(cluster, "t", serializer="raw")
    for i in range(10):
        prod.send(bytes([i]))
    c = Consumer(cluster, ConsumerGroup(cluster, "g", "t"), "m", deserialize=False)
    cluster.fail_node(cluster.topic("t").leaders[0], blackout=0.2)
    assert c.poll(100) == []  # election in progress: no data, no exception
    assert c.retries >= 1
    deadline = time.monotonic() + 2
    out = []
    while len(out) < 10 and time.monotonic() < deadline:
        out.extend(c.poll(100))
    assert [m.value for m in out] == [bytes([i]) for i in range(10)]


def test_consumer_max_lag_sheds_instead_of_falling_behind():
    cluster = BrokerCluster(1)
    cluster.create_topic("t", 1)
    prod = Producer(cluster, "t", serializer="raw")
    for _ in range(100):
        prod.send(b"x")
    c = Consumer(cluster, ConsumerGroup(cluster, "g", "t"), "m",
                 deserialize=False, max_lag=10)
    msgs = c.poll(1000)
    assert len(msgs) == 10
    assert msgs[0].offset == 90  # jumped to high_watermark - max_lag
    assert c.shed_records == 90


# ---------------------------------------------------------------------------
# supervisor restart backoff (restart-storm regression)
# ---------------------------------------------------------------------------


class _NullMonitor:
    def watch(self, *a, **kw):
        pass

    def unwatch(self, *a, **kw):
        pass


class _FakeSup(WorkerSupervisor):
    """Backoff policy under test, process machinery stubbed out."""

    def spawn(self):
        return self

    def kill(self):
        pass


def test_respawn_storm_backs_off_exponentially_with_cap():
    sup = _FakeSup(0, owner=None, window_fn=None, monitor=_NullMonitor(),
                   ctx=None, restart_backoff=0.01, restart_backoff_cap=0.04)
    t0 = time.monotonic()
    delays = [sup.respawn().last_backoff_s for _ in range(5)]
    storm = time.monotonic() - t0
    # first restart of a streak is immediate; then 0.01, 0.02, 0.04, 0.04 (cap)
    assert delays == [0.0, 0.01, 0.02, 0.04, 0.04]
    assert sup.restarts == 5
    assert storm >= 0.11  # the storm actually waited, not just recorded
    # a worker that survived a while gets an immediate restart again
    time.sleep(sup.restart_backoff_cap * 2 + 0.02)
    assert sup.respawn().last_backoff_s == 0.0


def test_isolated_crash_restarts_immediately():
    sup = _FakeSup(0, owner=None, window_fn=None, monitor=_NullMonitor(),
                   ctx=None, restart_backoff=0.5, restart_backoff_cap=5.0)
    t0 = time.monotonic()
    sup.respawn()
    assert time.monotonic() - t0 < 0.1
    assert sup.last_backoff_s == 0.0


# ---------------------------------------------------------------------------
# pilot failure detection + inline crash/recover
# ---------------------------------------------------------------------------


def test_inject_failure_fires_monitor_callbacks():
    svc = PilotComputeService(devices=[0, 1], heartbeat_interval=0.05,
                              heartbeat_timeout=0.1)
    try:
        pilot = svc.submit_pilot({"number_of_nodes": 1, "type": "flink"})
        failed = []
        svc.monitor.on_failure(failed.append)
        svc.inject_failure(pilot)
        deadline = time.monotonic() + 3
        while not failed and time.monotonic() < deadline:
            time.sleep(0.01)
        assert failed and failed[0] is pilot, (
            "monitor never reported the injected failure to its callbacks")
    finally:
        svc.cancel()


def _collecting_stream(cluster, results, **kw):
    return ContinuousStream(
        cluster, "t", group="g", assigner=TumblingWindow(0.1),
        window_fn=lambda key, w, msgs: (key, w, len(msgs)),
        key_fn=lambda m: m.value[0] % 3,
        emit=lambda out: results.__setitem__((out[0], out[1]), out[2]),
        **kw,
    )


def test_inline_crash_recover_is_bit_identical():
    def run(crash_at):
        cluster = BrokerCluster(1)
        cluster.create_topic("t", 1)
        from repro.broker.records import Record
        for i in range(300):
            # payloads 0..2 never collide with the serde tag bytes (N/M/Z)
            cluster.append("t", 0, Record(bytes([i % 3]), None, 1000.0 + i * 0.01))
        results: dict = {}
        stream = _collecting_stream(cluster, results, checkpoint_every=50)
        stream.start()
        deadline = time.monotonic() + 30
        if crash_at is not None:
            while stream.stats.fired_windows < crash_at:
                assert time.monotonic() < deadline
                time.sleep(0.002)
            stream.crash()
            assert stream._thread is None
            ms = stream.recover()
            assert ms >= 0.0 and stream.recoveries == 1
        # 300 records x 0.01s span 3.0s -> 29 closed 0.1s windows x 3 keys
        while stream.stats.fired_windows < 29 * 3:
            assert time.monotonic() < deadline, (
                f"{stream.stats.fired_windows}/87 windows fired")
            time.sleep(0.002)
        stream.stop()
        assert stream.stats.fired_windows == 87
        return results

    base = run(None)
    recovered = run(crash_at=30)
    assert recovered == base  # zero lost, zero duplicated firings


def test_recover_refuses_running_stream():
    cluster = BrokerCluster(1)
    cluster.create_topic("t", 1)
    stream = _collecting_stream(cluster, {}, checkpoint_every=10)
    stream.start()
    try:
        with pytest.raises(RuntimeError):
            stream.recover()
    finally:
        stream.stop()

"""Rescale chaos test (slow): random grow/shrink must be unobservable.

A ContinuousStream consumes a MASS source driven through a
RateStepScenario while a seeded chaos loop randomly submits and cancels
extension pilots mid-stream — every extend/shrink quiesces the record
loop and migrates the re-homed state partitions through the full serde
round trip. The run must fire the exact same windows with bit-identical
per-window aggregates as a static-resource baseline.

Determinism requires logical event time (wall-clock stamps differ across
runs): the source overrides ``make_timestamp``, and a single topic
partition + single keyed producer keep arrival order identical, so every
``(key, window)`` buffer accumulates the same float64 values in the same
order — any loss, duplication, or reorder during migration shows up as a
sum mismatch.
"""
import os
import random
import signal
import time

import numpy as np
import pytest

from repro.core import PilotComputeService
from repro.miniapps import RateStepScenario, SourceConfig
from repro.miniapps.mass import StreamSource
from repro.streaming import TumblingWindow

N_MSGS = 1500
DT = 0.01  # logical seconds between events
WINDOW = 0.1  # -> 10 msgs per window span
N_KEYS = 5
BASE_TS = 1000.0

# spans [BASE_TS + j*W, +W): the last span never closes (watermark stops at
# the final event), and every closed span holds 10 msgs = 2 per key
N_SPANS = N_MSGS * DT / WINDOW
EXPECTED_WINDOWS = (int(N_SPANS) - 1) * N_KEYS


class _DeterministicSource(StreamSource):
    """Payload and event time are pure functions of the message index."""

    def make_message(self, rng, i):
        return np.array([i % N_KEYS, float(i) * 1.25], dtype=np.float64)

    def make_timestamp(self, rng, i):
        return BASE_TS + i * DT


def _window_fn(key, w, msgs):
    vals = np.array([m.value[1] for m in msgs], dtype=np.float64)
    # np.sum order-sensitivity is the point: a migration that reorders a
    # buffer produces different low bits
    return key, w, float(np.sum(vals)), len(msgs)


def _run(chaos_seed: int | None, *, executor: str = "inline", cores: int = 2,
         kill_seed: int | None = None):
    """One full stream run; returns (results, fired, late, migrations,
    restarts). ``executor="mp"`` routes partitions to worker processes;
    ``kill_seed`` SIGKILLs one seeded-random worker mid-stream (mp only) —
    the supervisor must restart it from the checkpoint+journal spool."""
    svc = PilotComputeService(devices=list(range(10)))
    results: dict = {}
    migrations = restarts = 0
    try:
        kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
        cluster = kafka.get_context()
        cluster.create_topic("chaos", 1)
        flink = svc.submit_pilot(
            {"number_of_nodes": 1, "cores_per_node": cores, "type": "flink"})
        stream = flink.get_context().stream(
            cluster, "chaos", group="g",
            assigner=TumblingWindow(WINDOW),
            window_fn=_window_fn,
            key_fn=lambda m: int(m.value[0]),
            emit=lambda out: results.__setitem__((out[0], out[1]), (out[2], out[3])),
            executor=executor,
            worker_options={"snapshot_every": 8} if executor == "mp" else None,
        )
        stream.start()
        source = _DeterministicSource(cluster, SourceConfig(
            "chaos", total_messages=N_MSGS, n_producers=1, keyed=True, seed=7))
        scenario = RateStepScenario(
            source, [(0.4, 1000.0), (0.4, 4000.0), (0.4, 1800.0)], loop=True)
        source.start()
        scenario.start()

        rng = random.Random(chaos_seed) if chaos_seed is not None else None
        kill_rng = random.Random(kill_seed) if kill_seed is not None else None
        extensions: list = []
        deadline = time.monotonic() + 60
        while stream.stats.fired_windows < EXPECTED_WINDOWS:
            assert time.monotonic() < deadline, (
                f"{stream.stats.fired_windows}/{EXPECTED_WINDOWS} windows fired")
            if kill_rng is not None and stream.stats.fired_windows >= EXPECTED_WINDOWS // 3:
                # SIGKILL a seeded-random worker mid-window: the supervisor
                # must respawn it and replay checkpoint + journal. Issued from
                # this thread so it never lands inside a rescale handoff.
                sup = kill_rng.choice(stream.runtime._sups)
                os.kill(sup.process.pid, signal.SIGKILL)
                kill_rng = None
            if rng is None:
                time.sleep(0.02)
                continue
            # random mid-stream grow/shrink: each one quiesces + migrates
            if extensions and (len(extensions) >= 3 or rng.random() < 0.5):
                extensions.pop(rng.randrange(len(extensions))).cancel()
            else:
                extensions.append(svc.submit_pilot({
                    "number_of_nodes": 1,
                    "cores_per_node": rng.randint(1, 2),
                    "type": "flink",
                    "parent": flink,
                }))
            time.sleep(rng.uniform(0.01, 0.06))
        scenario.stop()
        source.stop()
        stream.stop()
        fired = stream.stats.fired_windows
        late = stream.stats.late_records
        migrations = len(stream.migrator.reports)
        restarts = stream.runtime.restarts if stream.runtime is not None else 0
    finally:
        svc.cancel()
    return results, fired, late, migrations, restarts


def _assert_bit_identical(base_results, other_results, label):
    assert other_results.keys() == base_results.keys(), label
    for kw, (total, count) in base_results.items():
        o_total, o_count = other_results[kw]
        assert o_count == count, f"{label}: window {kw}: {o_count} != {count} records"
        assert o_total == total, f"{label}: window {kw}: aggregate drifted"


@pytest.mark.slow
def test_windows_identical_under_random_rescale():
    base_results, base_fired, base_late, _, _ = _run(chaos_seed=None)
    chaos_results, chaos_fired, chaos_late, migrations, _ = _run(chaos_seed=20260729)

    assert base_late == chaos_late == 0
    assert migrations >= 3, "chaos run never actually migrated state"
    assert chaos_fired == base_fired == EXPECTED_WINDOWS
    # bit-identical: same window set, and exact float equality on sums
    _assert_bit_identical(base_results, chaos_results, "chaos rescale")


@pytest.mark.slow
def test_mp_executor_identical_under_chaos_and_worker_kill():
    """The mp executor must be unobservable relative to the inline
    single-process baseline, under three escalating scenarios:

    1. static resources, 4 worker processes;
    2. random grow/shrink chaos (every rescale quiesces workers, drains
       in-flight batches, and migrates partitions across processes);
    3. a seeded SIGKILL of a random worker mid-window — the supervisor
       restarts it and replays checkpoint + journal, so firings stay
       bit-identical with zero loss or duplication.
    """
    base_results, base_fired, base_late, _, _ = _run(chaos_seed=None)
    assert base_late == 0 and base_fired == EXPECTED_WINDOWS

    mp_results, mp_fired, mp_late, _, mp_restarts = _run(
        chaos_seed=None, executor="mp", cores=4)
    assert mp_late == 0 and mp_fired == EXPECTED_WINDOWS
    assert mp_restarts == 0
    _assert_bit_identical(base_results, mp_results, "mp static")

    ch_results, ch_fired, ch_late, ch_migrations, _ = _run(
        chaos_seed=20260730, executor="mp", cores=2)
    assert ch_late == 0 and ch_fired == EXPECTED_WINDOWS
    assert ch_migrations >= 3, "mp chaos run never actually migrated state"
    _assert_bit_identical(base_results, ch_results, "mp chaos rescale")

    k_results, k_fired, k_late, _, k_restarts = _run(
        chaos_seed=None, executor="mp", cores=4, kill_seed=20260731)
    assert k_late == 0 and k_fired == EXPECTED_WINDOWS
    assert k_restarts >= 1, "SIGKILL never triggered a supervisor restart"
    _assert_bit_identical(base_results, k_results, "mp worker kill")

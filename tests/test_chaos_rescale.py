"""Rescale chaos test (slow): random grow/shrink must be unobservable.

A ContinuousStream consumes a MASS source driven through a
RateStepScenario while a seeded chaos loop randomly submits and cancels
extension pilots mid-stream — every extend/shrink quiesces the record
loop and migrates the re-homed state partitions through the full serde
round trip. The run must fire the exact same windows with bit-identical
per-window aggregates as a static-resource baseline.

Determinism requires logical event time (wall-clock stamps differ across
runs): the source overrides ``make_timestamp``, and a single topic
partition + single keyed producer keep arrival order identical, so every
``(key, window)`` buffer accumulates the same float64 values in the same
order — any loss, duplication, or reorder during migration shows up as a
sum mismatch.
"""
import random
import time

import numpy as np
import pytest

from repro.core import PilotComputeService
from repro.miniapps import RateStepScenario, SourceConfig
from repro.miniapps.mass import StreamSource
from repro.streaming import TumblingWindow

N_MSGS = 1500
DT = 0.01  # logical seconds between events
WINDOW = 0.1  # -> 10 msgs per window span
N_KEYS = 5
BASE_TS = 1000.0

# spans [BASE_TS + j*W, +W): the last span never closes (watermark stops at
# the final event), and every closed span holds 10 msgs = 2 per key
N_SPANS = N_MSGS * DT / WINDOW
EXPECTED_WINDOWS = (int(N_SPANS) - 1) * N_KEYS


class _DeterministicSource(StreamSource):
    """Payload and event time are pure functions of the message index."""

    def make_message(self, rng, i):
        return np.array([i % N_KEYS, float(i) * 1.25], dtype=np.float64)

    def make_timestamp(self, rng, i):
        return BASE_TS + i * DT


def _window_fn(key, w, msgs):
    vals = np.array([m.value[1] for m in msgs], dtype=np.float64)
    # np.sum order-sensitivity is the point: a migration that reorders a
    # buffer produces different low bits
    return key, w, float(np.sum(vals)), len(msgs)


def _run(chaos_seed: int | None):
    svc = PilotComputeService(devices=list(range(10)))
    results: dict = {}
    migrations = 0
    try:
        kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
        cluster = kafka.get_context()
        cluster.create_topic("chaos", 1)
        flink = svc.submit_pilot(
            {"number_of_nodes": 1, "cores_per_node": 2, "type": "flink"})
        stream = flink.get_context().stream(
            cluster, "chaos", group="g",
            assigner=TumblingWindow(WINDOW),
            window_fn=_window_fn,
            key_fn=lambda m: int(m.value[0]),
            emit=lambda out: results.__setitem__((out[0], out[1]), (out[2], out[3])),
        )
        stream.start()
        source = _DeterministicSource(cluster, SourceConfig(
            "chaos", total_messages=N_MSGS, n_producers=1, keyed=True, seed=7))
        scenario = RateStepScenario(
            source, [(0.4, 1000.0), (0.4, 4000.0), (0.4, 1800.0)], loop=True)
        source.start()
        scenario.start()

        rng = random.Random(chaos_seed) if chaos_seed is not None else None
        extensions: list = []
        deadline = time.monotonic() + 60
        while stream.stats.fired_windows < EXPECTED_WINDOWS:
            assert time.monotonic() < deadline, (
                f"{stream.stats.fired_windows}/{EXPECTED_WINDOWS} windows fired")
            if rng is None:
                time.sleep(0.02)
                continue
            # random mid-stream grow/shrink: each one quiesces + migrates
            if extensions and (len(extensions) >= 3 or rng.random() < 0.5):
                extensions.pop(rng.randrange(len(extensions))).cancel()
            else:
                extensions.append(svc.submit_pilot({
                    "number_of_nodes": 1,
                    "cores_per_node": rng.randint(1, 2),
                    "type": "flink",
                    "parent": flink,
                }))
            time.sleep(rng.uniform(0.01, 0.06))
        scenario.stop()
        source.stop()
        stream.stop()
        fired = stream.stats.fired_windows
        late = stream.stats.late_records
        migrations = len(stream.migrator.reports)
    finally:
        svc.cancel()
    return results, fired, late, migrations


@pytest.mark.slow
def test_windows_identical_under_random_rescale():
    base_results, base_fired, base_late, _ = _run(chaos_seed=None)
    chaos_results, chaos_fired, chaos_late, migrations = _run(chaos_seed=20260729)

    assert base_late == chaos_late == 0
    assert migrations >= 3, "chaos run never actually migrated state"
    assert chaos_fired == base_fired == EXPECTED_WINDOWS
    # bit-identical: same window set, and exact float equality on sums
    assert chaos_results.keys() == base_results.keys()
    for kw, (total, count) in base_results.items():
        c_total, c_count = chaos_results[kw]
        assert c_count == count, f"window {kw}: {c_count} != {count} records"
        assert c_total == total, f"window {kw}: aggregate drifted"

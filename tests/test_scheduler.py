"""Resource arbitration (repro.scheduler): fair share, preemption,
grant/revoke idempotence, co-location, broker elasticity, and the
single-pipeline no-regression path.

Everything here drives the arbiter synchronously (``ctl.step()`` +
``arb.reconcile()``) against real in-process pilots, so the assertions are
deterministic — no sleeps against background threads except where a test
explicitly measures the wake-on-demand latency.
"""
import time

import pytest

from repro.core import PilotComputeService
from repro.elastic import (
    BrokerSaturationPolicy,
    ElasticConfig,
    ElasticController,
    MetricsBus,
    MetricsSnapshot,
    ThresholdHysteresisPolicy,
)
from repro.pipeline import Pipeline, PipelineSpec, PipelineValidationError, register_processor
from repro.scheduler import (
    HOSTS,
    OnlinePacker,
    PoolTenant,
    ResourceArbiter,
    ResourceRequest,
    colocation_groups,
    weighted_fair_share,
)


@register_processor("sched_noop")
def _noop(state, msgs):
    return (state or 0) + len(msgs)


def _elastic_pipeline(name, share=1.0, priority=0, max_devices=8, greedy=True):
    """One-stage pipeline whose estimator always wants more (high_lag=-1:
    any lag is 'too much'), so device splits are decided purely by the
    arbiter."""
    high, low = (-1.0, -2.0) if greedy else (1e9, -1.0)
    return (Pipeline.named(name).share(share)
            .topic("in", partitions=2)
            .source("in", kind="cluster", rate_msgs_per_s=30)
            .stage("work", topic="in", processor="sched_noop",
                   batch_interval=0.05, backpressure=False, priority=priority)
            .elastic("work", policy="threshold", high_lag=high, low_lag=low,
                     up_stable=1, interval=999.0, cooldown=0.0,
                     min_devices=1, max_devices=max_devices)
            .build())


# ---------------------------------------------------------------------------
# pure allocation
# ---------------------------------------------------------------------------


def test_weighted_fair_share_splits_by_weight():
    reqs = [ResourceRequest("a", min_devices=1, weight=2.0, target=100),
            ResourceRequest("b", min_devices=1, weight=1.0, target=100)]
    assert weighted_fair_share(reqs, 9) == {"a": 6, "b": 3}
    # demands below fair share are capped at demand, surplus flows on
    reqs = [ResourceRequest("a", min_devices=0, weight=2.0, target=2),
            ResourceRequest("b", min_devices=0, weight=1.0, target=100)]
    assert weighted_fair_share(reqs, 9) == {"a": 2, "b": 7}


def test_weighted_fair_share_priority_is_strict():
    reqs = [ResourceRequest("hi", min_devices=1, priority=1, target=6),
            ResourceRequest("lo", min_devices=1, priority=0, target=6)]
    alloc = weighted_fair_share(reqs, 8)
    assert alloc == {"hi": 6, "lo": 2}
    # floors always survive, even fully contended
    alloc = weighted_fair_share(reqs, 2)
    assert alloc == {"hi": 1, "lo": 1}


def test_request_validates_and_clamps_demand():
    with pytest.raises(ValueError):
        ResourceRequest("w", weight=0.0)
    with pytest.raises(ValueError):
        ResourceRequest("m", min_devices=4, max_devices=2)
    r = ResourceRequest("c", min_devices=2, max_devices=5, target=100)
    assert r.demand == 5
    r.set_target(0)
    assert r.demand == 2


# ---------------------------------------------------------------------------
# arbiter core (real pool, PoolTenant actuators)
# ---------------------------------------------------------------------------


def _tenant_arbiter(n_devices=8):
    svc = PilotComputeService(devices=list(range(n_devices)))
    return svc, ResourceArbiter(svc, MetricsBus())


def test_grant_and_revoke_are_idempotent():
    svc, arb = _tenant_arbiter()
    calls = []
    tenant = PoolTenant(svc)

    def counting_actuator(n):
        calls.append(n)
        return tenant.scale_to(n)

    req = tenant.request("t", min_devices=0, max_devices=8)
    req.actuator = counting_actuator
    arb.submit(req)
    arb.update("t", 4)
    arb.reconcile()
    assert tenant.devices == 4 and calls == [4]
    # unchanged demand: repeated reconciles must not re-actuate
    arb.reconcile()
    arb.reconcile()
    assert calls == [4]
    arb.update("t", 1)
    arb.reconcile()
    assert tenant.devices == 1 and calls == [4, 1]
    assert svc.pool.free_devices == 7
    # the revocation is recorded as a voluntary revoke, not a preemption
    assert [e.action for e in arb.events] == ["grant", "revoke"]


def test_preemption_frees_devices_for_higher_priority():
    svc, arb = _tenant_arbiter(n_devices=6)
    lo = PoolTenant(svc)
    arb.submit(lo.request("lo", min_devices=1, priority=0))
    arb.update("lo", 6)
    arb.reconcile()
    assert lo.devices == 6
    hi = PoolTenant(svc)
    arb.submit(hi.request("hi", min_devices=0, priority=1))
    arb.update("hi", 4)
    arb.reconcile()
    assert hi.devices == 4
    assert lo.devices == 2
    preempts = [e for e in arb.events if e.action == "preempt"]
    assert len(preempts) == 1 and preempts[0].delta == -4
    assert arb.preemptions == 1
    # shrink-before-grow within one pass: nothing left unplaced
    assert svc.pool.free_devices == 0


def test_preemption_lands_within_one_background_interval():
    svc, arb = _tenant_arbiter(n_devices=6)
    arb.interval = 5.0  # wake-on-update must beat the slow timer
    lo = PoolTenant(svc)
    arb.submit(lo.request("lo", min_devices=1, priority=0))
    arb.update("lo", 6)
    arb.start()
    try:
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline and lo.devices < 6:
            time.sleep(0.01)
        assert lo.devices == 6
        hi = PoolTenant(svc)
        arb.submit(hi.request("hi", min_devices=0, priority=1))
        t0 = time.monotonic()
        arb.update("hi", 4)
        while time.monotonic() < deadline and hi.devices < 4:
            time.sleep(0.01)
        latency = time.monotonic() - t0
        assert hi.devices == 4 and lo.devices == 2
        assert latency < arb.interval, (
            f"preemption took {latency:.2f}s — the demand filing should wake "
            f"the loop, not wait out the {arb.interval}s interval"
        )
    finally:
        arb.stop()


def test_static_reservations_participate_without_actuation():
    svc, arb = _tenant_arbiter(n_devices=4)
    pilot = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 2,
                              "type": "spark"})
    arb.submit(ResourceRequest(
        "static", min_devices=2, max_devices=2, target=2,
        current_fn=lambda: len(pilot.lease.devices)))
    t = PoolTenant(svc)
    arb.submit(t.request("greedy", min_devices=0))
    arb.update("greedy", 99)
    arb.reconcile()
    # the reservation's devices were never handed to the greedy tenant
    assert t.devices == 2
    assert len(pilot.lease.devices) == 2


def test_pure_reservation_floor_survives_repeated_reconciles():
    """A request with neither actuator nor current_fn holds nothing — its
    grant must not be double-counted as arbitrable capacity, or a greedy
    tenant erodes the reserved floor on the second tick."""
    svc, arb = _tenant_arbiter(n_devices=8)
    arb.submit(ResourceRequest("reserved", min_devices=3, target=3))
    t = PoolTenant(svc)
    arb.submit(t.request("greedy", min_devices=0))
    arb.update("greedy", 8)
    for _ in range(4):
        arb.reconcile()
        assert t.devices == 5, "the 3-device reservation must hold every tick"


# ---------------------------------------------------------------------------
# acceptance: two PipelineRuns, 2:1 shares, constrained pool
# ---------------------------------------------------------------------------


def test_two_runs_with_2_to_1_shares_converge_to_2_to_1_split():
    bus = MetricsBus()
    svc = PilotComputeService(devices=list(range(9)), metrics=bus)
    run_a = _elastic_pipeline("shareA", share=2.0).run(service=svc, bus=bus).start()
    run_b = _elastic_pipeline("shareB", share=1.0).run(service=svc, bus=bus).start()
    try:
        arb = svc.arbiter
        assert run_a.arbiter is arb and run_b.arbiter is arb, \
            "both runs must share the service's one arbiter"
        ca, cb = run_a.controller("work"), run_b.controller("work")
        for _ in range(12):
            ca.step()
            cb.step()
            arb.reconcile()
        assert (ca.devices, cb.devices) == (6, 3), \
            f"expected 2:1 split of 9 devices, got {ca.devices}:{cb.devices}"
        # the decision trail is on the bus
        assert bus.value("scheduler.granted", request="shareA/work") == 6
        assert bus.value("scheduler.granted", request="shareB/work") == 3
    finally:
        run_a.stop()
        run_b.stop()
        svc.cancel()
    assert svc.pool.leased_devices == 0


def test_priority_stage_preempts_lower_priority_run():
    bus = MetricsBus()
    svc = PilotComputeService(devices=list(range(6)), metrics=bus)
    lo_run = _elastic_pipeline("loP", priority=0, max_devices=6).run(
        service=svc, bus=bus).start()
    try:
        clo = lo_run.controller("work")
        arb = svc.arbiter
        for _ in range(8):
            clo.step()
            arb.reconcile()
        assert clo.devices >= 5  # low-priority filled the pool
        hi_run = _elastic_pipeline("hiP", priority=1, max_devices=4).run(
            service=svc, bus=bus).start()
        try:
            chi = hi_run.controller("work")
            before = clo.devices
            for _ in range(6):
                chi.step()
                arb.reconcile()
            assert chi.devices == 4
            assert clo.devices < before
            assert clo.devices >= 1  # floor honored
            assert any(e.action == "preempt" for e in arb.events)
        finally:
            hi_run.stop()
    finally:
        lo_run.stop()
        svc.cancel()


# ---------------------------------------------------------------------------
# no-regression: a single pipeline behaves as in the pre-arbiter world
# ---------------------------------------------------------------------------


def test_single_run_grants_exactly_what_the_estimator_asks():
    """Alone on the pool, the arbiter is a pass-through: every demand step
    lands verbatim (the direct-mode trajectory), grow and shrink."""
    spec = (Pipeline.named("solo")
            .topic("in", partitions=2)
            .source("in", kind="cluster", rate_msgs_per_s=30)
            .stage("work", topic="in", processor="sched_noop",
                   batch_interval=0.05, backpressure=False)
            .elastic("work", policy="threshold", high_lag=80, low_lag=15,
                     up_stable=1, down_stable=1, interval=999.0, cooldown=0.0,
                     min_devices=1, max_devices=6, devices_per_step=2)
            .build())
    with spec.run(devices=8) as run:
        ctl = run.controller("work")
        arb = run.arbiter
        bus = run.bus
        label = ctl.stream

        def drive(lag):
            bus.publish("stream.lag", lag, stream=label)
            bus.publish("stream.busy_frac", 0.0, stream=label)
            ctl.lag_probe = lambda: lag
            ctl.step()
            arb.reconcile()

        assert ctl.devices == 1
        drive(500)  # above high watermark -> +devices_per_step
        assert ctl.devices == 3
        drive(500)
        assert ctl.devices == 5
        drive(0)  # drained -> -devices_per_step
        assert ctl.devices == 3
        drive(0)
        assert ctl.devices == 1  # never below min_devices
        drive(0)
        assert ctl.devices == 1
        ups = ctl.events.of("scale_up")
        downs = ctl.events.of("scale_down")
        assert len(ups) == 2 and len(downs) == 2
    assert run.service.pool.leased_devices == 0


def test_controller_without_arbiter_is_unchanged_direct_mode():
    """The pre-scheduler imperative path still works byte-for-byte: no
    arbiter, controller actuates itself."""
    bus = MetricsBus()
    svc = PilotComputeService(devices=list(range(4)), metrics=bus)
    kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
    kafka.get_context().create_topic("t", 1)
    pilot = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 1,
                              "type": "spark"})
    ctl = ElasticController(
        svc, pilot, bus,
        ThresholdHysteresisPolicy(high_lag=10, low_lag=1, up_stable=1),
        config=ElasticConfig(cooldown=0.0),
        lag_probe=lambda: 100.0,
    )
    assert ctl.arbiter is None
    ctl.step()
    assert ctl.devices == 2  # grew immediately, no arbiter in the loop
    svc.cancel()


# ---------------------------------------------------------------------------
# co-location
# ---------------------------------------------------------------------------


def test_colocated_stages_share_one_pilot_and_rescale_together():
    spec = (Pipeline.named("colo")
            .topic("a", partitions=2).topic("b", partitions=2)
            .source("a", kind="cluster", rate_msgs_per_s=30, total_messages=8)
            .source("b", kind="cluster", rate_msgs_per_s=30, total_messages=8)
            .stage("host", topic="a", processor="sched_noop",
                   cores_per_node=2, batch_interval=0.05, backpressure=False)
            .stage("guest", topic="b", processor="sched_noop",
                   colocate_with="host", batch_interval=0.05,
                   backpressure=False)
            .build())
    with spec.run(devices=4) as run:
        assert run.pilot("guest") is run.pilot("host")
        # only the host's pilot leased devices (no second engine pilot)
        assert run.service.pool.leased_devices == 2
        run.await_batches("host", 1, timeout=20)
        run.await_batches("guest", 1, timeout=20)
    assert run.service.pool.leased_devices == 0


def test_arbiter_placement_packs_colocated_requests_into_one_bin():
    svc, arb = _tenant_arbiter(n_devices=8)
    arb.submit(ResourceRequest("p/x", min_devices=2, target=2))
    arb.submit(ResourceRequest("p/y", min_devices=1, target=1,
                               colocate_with="p/x"))
    arb.submit(ResourceRequest("p/z", min_devices=3, target=3))
    bins = arb.placement(bin_size=4)
    by_member = {m: i for i, b in enumerate(bins) for m in b}
    assert by_member["p/x"] == by_member["p/y"], \
        "co-located requests must land in the same bin"
    assert by_member["p/z"] != by_member["p/x"]


def test_builder_validates_colocation_targets():
    def build(**kw):
        return (Pipeline.named("v")
                .topic("a")
                .stage("host", topic="a", processor="sched_noop")
                .stage("guest", topic="a", processor="sched_noop", **kw)
                .build())

    with pytest.raises(PipelineValidationError, match="unknown co-location"):
        build(colocate_with="ghost")
    with pytest.raises(PipelineValidationError, match="cannot colocate_with itself"):
        (Pipeline.named("v").topic("a")
         .stage("s", topic="a", processor="sched_noop", colocate_with="s")
         .build())
    with pytest.raises(PipelineValidationError, match="share one pilot"):
        (Pipeline.named("v").topic("a")
         .stage("host", topic="a", processor="sched_noop", engine="continuous",
                window={"window": "tumbling", "size": 0.5})
         .stage("guest", topic="a", processor="sched_noop",
                colocate_with="host")
         .build())
    with pytest.raises(PipelineValidationError, match="cannot have its own elastic"):
        (Pipeline.named("v").topic("a")
         .stage("host", topic="a", processor="sched_noop")
         .stage("guest", topic="a", processor="sched_noop",
                colocate_with="host")
         .elastic("guest", policy="threshold", high_lag=1, low_lag=0)
         .build())


# ---------------------------------------------------------------------------
# gang scheduling (all-or-nothing co-located grants)
# ---------------------------------------------------------------------------


def test_colocation_groups_chase_roots_and_tolerate_cycles():
    x = ResourceRequest("x")
    y = ResourceRequest("y", colocate_with="x")
    z = ResourceRequest("z", colocate_with="y")  # chains collapse to the root
    solo = ResourceRequest("solo")
    groups = colocation_groups([x, y, z, solo])
    assert sorted(r.name for r in groups["x"]) == ["x", "y", "z"]
    assert [r.name for r in groups["solo"]] == ["solo"]
    # a dangling target is its own root; a cycle doesn't hang
    dangling = ResourceRequest("d", colocate_with="ghost")
    a = ResourceRequest("a", colocate_with="b")
    b = ResourceRequest("b", colocate_with="a")
    groups = colocation_groups([dangling, a, b])
    assert [r.name for r in groups["d"]] == ["d"]
    assert sum(len(g) for g in groups.values()) == 3


def test_gang_allocation_withholds_partial_groups():
    """Contention must never leave a co-located group half-runnable: if
    fair share would grant one member and starve its sibling, the whole
    gang is withheld and the capacity goes to whoever can use it."""
    svc, arb = _tenant_arbiter(n_devices=3)
    hi = PoolTenant(svc)
    arb.submit(hi.request("hi", min_devices=0, priority=1))
    arb.update("hi", 2)
    gx = ResourceRequest("g/x", min_devices=0, target=2)
    gy = ResourceRequest("g/y", min_devices=0, target=2, colocate_with="g/x")
    arb.submit(gx)
    arb.submit(gy)
    alloc = arb.allocate()
    # 3 devices: hi takes 2, the 1 leftover cannot run both gang members
    assert alloc["hi"] == 2
    assert alloc["g/x"] == 0 and alloc["g/y"] == 0, \
        f"partial gang grant leaked through: {alloc}"
    # without the contender the gang is whole
    arb.withdraw("hi")
    alloc = arb.allocate()
    assert alloc["g/x"] >= 1 and alloc["g/y"] >= 1


def test_gang_actuation_rolls_back_on_member_failure():
    """A gang member whose actuator blows up (or under-delivers) must undo
    every sibling already actuated this pass — no partially-placed gang."""
    svc, arb = _tenant_arbiter(n_devices=8)
    tx = PoolTenant(svc)
    rx = tx.request("g/x", min_devices=0)
    arb.submit(rx)

    def exploding(n):
        raise RuntimeError("placement failed")

    ry = ResourceRequest("g/y", min_devices=0, colocate_with="g/x",
                         actuator=exploding, current_fn=lambda: 0)
    arb.submit(ry)
    arb.update("g/x", 2)
    arb.update("g/y", 2)
    granted = arb.reconcile()
    assert tx.devices == 0, "surviving member kept its grant after rollback"
    assert granted.get("g/x", 0) == 0
    assert any(e.action == "gang_rollback" for e in arb.events)
    assert arb.bus.value("scheduler.errors", request="g/y") == 1.0
    # under-delivery (reached != want) triggers the same rollback
    svc2, arb2 = _tenant_arbiter(n_devices=8)
    t2 = PoolTenant(svc2)
    arb2.submit(t2.request("h/x", min_devices=0))
    short_state = {"n": 0}

    def short(n):
        short_state["n"] = max(n - 1, 0)  # always one device short
        return short_state["n"]

    arb2.submit(ResourceRequest("h/y", min_devices=0, colocate_with="h/x",
                                actuator=short,
                                current_fn=lambda: short_state["n"]))
    arb2.update("h/x", 2)
    arb2.update("h/y", 2)
    arb2.reconcile()
    assert t2.devices == 0
    assert any(e.action == "gang_rollback" for e in arb2.events)


def test_singleton_clamped_grant_still_stands():
    """Rollback semantics are gang-only: a lone request whose actuator
    reaches less than the allocation keeps what it got (old behavior)."""
    svc, arb = _tenant_arbiter(n_devices=8)
    held = {"n": 0}

    def clamping(n):
        held["n"] = min(n, 3)  # consumer-side cap
        return held["n"]

    arb.submit(ResourceRequest("solo", min_devices=0, actuator=clamping,
                               current_fn=lambda: held["n"]))
    arb.update("solo", 6)
    granted = arb.reconcile()
    assert held["n"] == 3
    assert granted["solo"] == 3
    assert not any(e.action == "gang_rollback" for e in arb.events)


# ---------------------------------------------------------------------------
# online bin packing
# ---------------------------------------------------------------------------


def test_online_packer_amends_instead_of_repacking():
    p = OnlinePacker(4)
    p.repack({"a": 2.0, "b": 2.0, "c": 3.0})
    first = {g: p.bin_of(g) for g in "abc"}
    assert first["a"] == first["b"] != first["c"]  # a+b share, c alone
    # identical demands: nothing moves, nothing is counted
    p.repack({"a": 2.0, "b": 2.0, "c": 3.0})
    assert {g: p.bin_of(g) for g in "abc"} == first
    assert p.relocations == 0
    # shrink is always in place
    p.repack({"a": 1.0, "b": 2.0, "c": 3.0})
    assert p.bin_of("a") == first["a"] and p.relocations == 0
    # grow that overflows the shared bin relocates ONLY the grower
    p.repack({"a": 3.0, "b": 2.0, "c": 3.0})
    assert p.bin_of("b") == first["b"], "innocent bystander was moved"
    assert p.bin_of("a") != first["a"]
    assert p.relocations == 1
    # arrivals go first-fit into existing bins; incumbents stay put
    before = {g: p.bin_of(g) for g in "abc"}
    p.repack({"a": 3.0, "b": 2.0, "c": 3.0, "d": 1.0})
    assert {g: p.bin_of(g) for g in "abc"} == before
    assert p.bin_of("d") is not None
    assert p.relocations == 1  # placement of an arrival is not churn


def test_online_packer_departures_and_oversize():
    p = OnlinePacker(4)
    p.repack({"a": 2.0, "b": 2.0})
    # zero / missing demand unplaces the group and drops empty bins
    bins = p.repack({"b": 2.0, "z": 0.0})
    assert p.bin_of("a") is None and p.bin_of("z") is None
    assert bins == [["b"]]
    # an oversized group still gets a dedicated bin (FFD behavior), and
    # growing alone in its bin never relocates
    p.repack({"b": 2.0, "big": 9.0})
    i = p.bin_of("big")
    p.repack({"b": 2.0, "big": 11.0})
    assert p.bin_of("big") == i and p.relocations == 0
    with pytest.raises(ValueError):
        OnlinePacker(0)
    p.reset(8)
    assert p.bins == [] and p.capacity == 8


def test_arbiter_placement_is_sticky_across_ticks():
    svc, arb = _tenant_arbiter(n_devices=8)
    arb.submit(ResourceRequest("p/x", min_devices=2, target=2))
    arb.submit(ResourceRequest("p/y", min_devices=1, target=1,
                               colocate_with="p/x"))
    arb.submit(ResourceRequest("p/z", min_devices=3, target=3))
    first = arb.placement(bin_size=4)
    for _ in range(3):
        assert arb.placement(bin_size=4) == first, \
            "unchanged demands must not reshuffle bins"
    assert arb.bus.value("scheduler.relocations") == 0
    # a new request lands without disturbing the incumbents' bins
    arb.submit(ResourceRequest("p/w", min_devices=1, target=1))
    second = arb.placement(bin_size=4)
    flat_first = {m for b in first for m in b}
    assert flat_first <= {m for b in second for m in b}
    incumbent_bins = [
        [m for m in b if m in flat_first] for b in second]
    assert [b for b in incumbent_bins if b] == first


# ---------------------------------------------------------------------------
# broker elasticity through the arbiter
# ---------------------------------------------------------------------------


def test_broker_elastic_spec_drives_cluster_nodes_through_arbiter():
    spec = (Pipeline.named("bk")
            .broker(nodes=1, io_rate_per_node=1e9)
            .broker_elastic(policy="broker_saturation", min_nodes=1,
                            max_nodes=4)
            .topic("t", partitions=4)
            .source("t", kind="cluster", rate_msgs_per_s=20)
            .stage("s", topic="t", processor="sched_noop",
                   batch_interval=0.05, backpressure=False)
            .build())
    assert spec.broker.elastic.policy == "broker_saturation"
    assert PipelineSpec.from_dict(spec.to_dict()) == spec
    with spec.run(devices=2) as run:
        assert run.cluster.n_nodes == 1
        name = "bk/__broker__"
        req = run.arbiter.request(name)
        assert req.unit == HOSTS
        # grant -> extension pilots on the broker pilot -> add_node
        run.arbiter.update(name, 3)
        run.arbiter.reconcile()
        assert run.cluster.n_nodes == 3
        # broker nodes never consume pool devices (host slots only)
        assert run.service.pool.leased_devices == 1
        run.arbiter.update(name, 1)
        run.arbiter.reconcile()
        assert run.cluster.n_nodes == 1
        acts = [e.action for e in run.broker_controller.events]
        assert acts == ["scale_up", "scale_down"]
    assert run.service.pool.leased_devices == 0


def test_broker_saturation_policy_hysteresis():
    def snap(stall):
        return MetricsSnapshot(
            t=0.0, lag=0.0, records_per_sec=0.0, processing_delay=0.0,
            scheduling_delay=0.0, busy_frac=0.0, devices_total=8,
            devices_leased=0, utilization=0.0, broker_stall_frac=stall,
        )

    p = BrokerSaturationPolicy(high_stall=0.3, low_stall=0.02,
                               up_stable=2, down_stable=2)
    assert p.decide(snap(0.5)).delta_devices == 0  # first observation
    d = p.decide(snap(0.5))
    assert d.scale_up and d.delta_devices == 1
    assert p.decide(snap(0.1)).delta_devices == 0  # between bands: hold
    assert p.decide(snap(0.0)).delta_devices == 0
    d = p.decide(snap(0.0))
    assert d.scale_down


def test_token_bucket_stall_seconds_accumulate():
    from repro.broker.cluster import BrokerCluster
    from repro.broker.records import Record

    cluster = BrokerCluster(n_nodes=1, io_rate_per_node=2048.0)
    cluster.create_topic("t", 1)
    payload = bytes(1024)
    for _ in range(8):  # ~8 KiB through a 2 KiB/s bucket -> must stall
        cluster.append("t", 0, Record(payload, None, time.time()))
    assert cluster.io_stall_seconds() > 0.5


# ---------------------------------------------------------------------------
# spec/serde of the new fields
# ---------------------------------------------------------------------------


def test_new_spec_fields_round_trip_and_default_sanely():
    spec = (Pipeline.named("rt2").share(2.5)
            .broker(nodes=2)
            .broker_elastic(min_nodes=2, max_nodes=6, high_stall=0.4)
            .topic("a", partitions=2)
            .stage("x", topic="a", processor="sched_noop",
                   priority=3, share=1.5)
            .stage("y", topic="a", processor="sched_noop", colocate_with="x")
            .build())
    rt = PipelineSpec.from_dict(spec.to_dict())
    assert rt == spec
    assert rt.share == 2.5
    assert rt.stage("x").priority == 3 and rt.stage("x").share == 1.5
    assert rt.stage("y").colocate_with == "x"
    assert rt.broker.elastic.params == {"high_stall": 0.4}
    # defaults: old specs (no new fields) still deserialize
    old = {"name": "old", "broker": {"topics": {"a": 1}},
           "stages": [{"name": "s", "topic": "a", "processor": "sched_noop"}]}
    loaded = PipelineSpec.from_dict(old)
    assert loaded.share == 1.0
    assert loaded.stages[0].priority == 0
    assert loaded.stages[0].colocate_with is None
    assert loaded.broker.elastic is None


def test_cli_validate_catches_scheduler_field_errors(tmp_path):
    from repro.pipeline.cli import main

    spec = (Pipeline.named("cli")
            .topic("a", partitions=1)
            .stage("s", topic="a", processor="sched_noop")
            .build())
    bad = spec.to_dict()
    bad["stages"][0]["colocate_with"] = "ghost"
    bad["stages"][0]["share"] = -1.0
    p = tmp_path / "bad.json"
    import json

    p.write_text(json.dumps(bad))
    assert main(["validate", str(p)]) == 1
    good = tmp_path / "good.json"
    good.write_text(spec.to_json())
    assert main(["validate", str(good)]) == 0

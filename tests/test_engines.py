"""Engines: micro-batch exactly-once, PID backpressure, continuous windows,
taskpool speculative execution, pilot lifecycle + failure recovery."""
import threading
import time

import numpy as np
import pytest

from repro.broker import BrokerCluster, Producer
from repro.core import CUState, PilotComputeDescription, PilotComputeService
from repro.streaming import PIDRateController, TumblingWindow


@pytest.fixture
def svc():
    s = PilotComputeService()
    yield s
    s.cancel()


def _broker(svc, topics):
    pilot = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
    cluster = pilot.get_context()
    for t, p in topics:
        cluster.create_topic(t, p)
    return pilot, cluster


def test_pilot_startup_and_states(svc):
    pilot = svc.submit_pilot({"number_of_nodes": 1, "type": "dask"})
    assert pilot.state.value == "Running"
    assert pilot.startup_time is not None and pilot.startup_time < 5


def test_exactly_once_replay_after_crash(svc):
    """Crash between checkpoint and failure: recovery rewinds to committed
    offsets and recomputes the same state."""
    _, cluster = _broker(svc, [("t", 2)])
    spark = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"})
    ctx = spark.get_context()
    prod = Producer(cluster, "t", serializer="npy")
    for i in range(16):
        prod.send(np.array([float(i)]))

    checkpoints = []

    def ckpt(state, offsets):
        checkpoints.append((state, dict(offsets)))

    def process(state, msgs):
        return (state or 0.0) + sum(float(m.value[0]) for m in msgs)

    s = ctx.stream(cluster, "t", group="g", process_fn=process, batch_interval=0.02,
                   max_batch_records=4, backpressure=False, checkpoint_fn=ckpt)
    s.start()
    s.await_batches(4, timeout=20)
    s.stop()
    final = s.state

    # simulate crash + recovery from the SECOND checkpoint: replay the rest
    state, offsets = checkpoints[1]
    s2 = ctx.stream(cluster, "t", group="g2", process_fn=process, batch_interval=0.02,
                    max_batch_records=4, backpressure=False)
    s2.recover(state, offsets)
    s2.start()
    deadline = time.monotonic() + 20
    while sum(s2.lag().values()) > 0 and time.monotonic() < deadline:
        time.sleep(0.05)
    s2.stop()
    assert s2.state == final == sum(range(16))


def test_pid_controller_reduces_rate_under_overload():
    pid = PIDRateController(batch_interval=0.1)
    r1 = pid.update(n_records=1000, processing_delay=0.1)  # at capacity
    r2 = pid.update(n_records=1000, processing_delay=0.4)  # 4x overloaded
    assert r2 < r1
    assert pid.max_records_per_batch < 1000


def test_continuous_event_time_windows(svc):
    _, cluster = _broker(svc, [("ev", 1)])
    flink = svc.submit_pilot({"number_of_nodes": 1, "type": "flink"})
    ctx = flink.get_context()
    outputs = []

    def window_fn(key, window, msgs):
        return (window, sum(float(m.value[0]) for m in msgs))

    s = ctx.stream(cluster, "ev", group="w", assigner=TumblingWindow(10.0),
                   window_fn=window_fn, emit=outputs.append)
    s.start()
    prod = Producer(cluster, "ev", serializer="npy")
    base = 1000.0
    for ts, v in [(1, 1.0), (2, 2.0), (11, 10.0), (3, 99.0), (25, 5.0)]:
        prod.send(np.array([v]), timestamp=base + ts)
    s.await_windows(2, timeout=20)
    s.stop()
    # window [1000,1010) fired with 1+2 (+99 if not late: watermark only moved
    # to 1011 when (11,10.0) arrived -> (3,99.0) is NOT late with lateness=0? it is: 1003 < 1011
    fired = {tuple(np.round(w, 1)): v for (w, v) in outputs}
    assert fired[(1000.0, 1010.0)] == 3.0
    assert fired[(1010.0, 1020.0)] == 10.0
    assert s.stats.late_records == 1


def test_taskpool_speculative_execution(svc):
    pilot = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 4, "type": "dask",
                              "speculative_multiple": 2.0})
    plugin = pilot.get_context()
    state = {"hung": True}

    def quick(i):
        time.sleep(0.02)
        return i

    def straggler():
        # first attempt hangs; the speculative duplicate returns immediately
        if state.pop("hung", None):
            time.sleep(30)
            return "slow"
        return "fast"

    cus = [pilot.submit(quick, i) for i in range(8)]
    for cu in cus:
        cu.wait(10)
    slow_cu = pilot.submit(straggler)
    assert slow_cu.wait(15) == "fast"
    assert plugin.speculated >= 1
    assert slow_cu.attempts >= 2


def test_taskpool_extend_and_shrink(svc):
    pilot = svc.submit_pilot({"number_of_nodes": 1, "cores_per_node": 2, "type": "dask"})
    plugin = pilot.get_context()
    assert plugin.n_workers == 2
    ext = svc.submit_pilot(PilotComputeDescription(number_of_nodes=1, cores_per_node=2,
                                                   framework="dask", parent=pilot))
    assert plugin.n_workers == 4
    ext.cancel()
    assert plugin.n_workers == 2


def test_cu_failure_propagates(svc):
    pilot = svc.submit_pilot({"number_of_nodes": 1, "type": "dask"})

    def boom():
        raise ValueError("exploded")

    cu = pilot.submit(boom)
    with pytest.raises(ValueError, match="exploded"):
        cu.wait(10)
    assert cu.state == CUState.FAILED


def test_broker_failure_keeps_pipeline_alive(svc):
    kafka = svc.submit_pilot({"number_of_nodes": 2, "type": "kafka"})
    cluster = kafka.get_context()
    cluster.create_topic("t", 4)
    ext = svc.submit_pilot(PilotComputeDescription(number_of_nodes=1, framework="kafka",
                                                   parent=kafka))
    n_before = cluster.n_nodes
    svc.inject_failure(ext)  # involuntary shrink
    assert cluster.n_nodes == n_before - 1
    prod = Producer(cluster, "t", serializer="raw")
    assert prod.send(b"still alive") >= 0


def test_continuous_async_emit_equivalent_and_exactly_once_after_crash():
    """The emit double-buffer (docs/perf.md): same fired windows, same
    delivered outputs as the synchronous path — including across a crash
    (pending emits are discarded and re-fired by the replay exactly once)."""
    from repro.engines.continuous import ContinuousStream

    def run(async_emit, crash_at=None):
        cluster = BrokerCluster(1)
        cluster.create_topic("t", 1)
        results = []
        stream = ContinuousStream(
            cluster, "t", group="g", assigner=TumblingWindow(0.1),
            window_fn=lambda key, w, msgs: (key, w, float(np.sum(
                [m.value[1] for m in msgs])), len(msgs)),
            key_fn=lambda m: int(m.value[0]) % 3,
            emit=results.append,
            checkpoint_every=40,
            async_emit=async_emit,
        )
        assert (stream._emit_window is not None) == (async_emit > 0)
        stream.start()
        prod = Producer(cluster, "t")
        for b in range(30):
            vals = [np.array([(b * 10 + j) % 3, float(b * 10 + j) * 1.25])
                    for j in range(10)]
            ts = [1000.0 + (b * 10 + j) * 0.01 for j in range(10)]
            prod.send_batch(vals, timestamps=ts)
            if crash_at is not None and b == crash_at:
                time.sleep(0.15)
                stream.crash()
                stream.recover()
        # ~29 full windows x 3 keys fire; the last partial ones never do
        stream.await_windows(80, timeout=20)
        time.sleep(0.2)
        stream.stop()
        assert stream.stats.fired_windows == len(results)
        cluster.close()
        return sorted(results)

    sync_out = run(0)
    async_out = run(3)
    assert async_out == sync_out
    crashed = run(3, crash_at=18)
    assert len(crashed) == len(set(crashed)), "duplicated window delivery"
    assert crashed == sync_out

"""Optimizer math: AdamW reference equivalence, Adafactor, clipping, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.optimizer import (
    Optimizer,
    OptimizerConfig,
    clip_by_global_norm,
    global_norm,
    lr_at,
)


def _tree():
    return {
        "w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32),
        "b": jnp.asarray(np.random.default_rng(1).normal(size=(8,)), jnp.float32),
    }


def test_adamw_matches_manual_math():
    cfg = OptimizerConfig(name="adamw", learning_rate=1e-2, warmup_steps=0, schedule="constant",
                          clip_norm=1e9, weight_decay=0.0)
    opt = Optimizer(cfg)
    params = _tree()
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
    state = opt.init(params)
    new_params, new_state, stats = opt.update(grads, state, params)
    # manual: m=0.01, v=0.00095^... b1=0.9,b2=0.95: m1=(1-b1)*g=0.01; v1=(1-b2)*g^2=5e-4
    # mhat=m1/(1-b1)=0.1; vhat=v1/(1-b2)=0.01; delta=0.1/(0.1+eps)≈1.0
    expect = 1e-2 * 0.1 / (jnp.sqrt(jnp.float32(0.01)) + cfg.eps)
    np.testing.assert_allclose(
        np.asarray(params["w"] - new_params["w"]), np.full((4, 8), float(expect)), rtol=1e-5
    )


def test_adamw_weight_decay_only_on_matrices():
    cfg = OptimizerConfig(name="adamw", learning_rate=1e-2, warmup_steps=0, schedule="constant",
                          weight_decay=0.1, clip_norm=1e9)
    opt = Optimizer(cfg)
    params = _tree()
    zeros = jax.tree.map(jnp.zeros_like, params)
    state = opt.init(params)
    new_params, _, _ = opt.update(zeros, state, params)
    assert not np.allclose(np.asarray(new_params["w"]), np.asarray(params["w"]))  # decayed
    np.testing.assert_allclose(np.asarray(new_params["b"]), np.asarray(params["b"]))  # biases skipped


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    norm = float(global_norm(tree))
    np.testing.assert_allclose(norm, np.sqrt(10 * 9 + 10 * 16), rtol=1e-6)
    clipped, _ = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-3)


def test_leaf_sqnorm_layerwise_path_matches_direct():
    big = jnp.asarray(np.random.default_rng(2).normal(size=(16, 64, 64 * 64)), jnp.float32)
    direct = float(jnp.sum(jnp.square(big)))
    from repro.runtime.optimizer import _leaf_sqnorm

    np.testing.assert_allclose(float(_leaf_sqnorm(big)), direct, rtol=1e-5)


def test_layerwise_update_equals_whole_leaf_update():
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16, 64, 4096)), jnp.float32)}
    grads = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(16, 64, 4096)), jnp.float32) * 0.01}
    outs = []
    for layerwise in (True, False):
        cfg = OptimizerConfig(name="adamw", layerwise_update=layerwise, warmup_steps=0,
                              schedule="constant")
        opt = Optimizer(cfg)
        st = opt.init(params)
        p2, _, _ = opt.update(grads, st, params)
        outs.append(np.asarray(p2["w"]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)


def test_adafactor_factored_state_is_small_and_converges():
    cfg = OptimizerConfig(name="adafactor", learning_rate=0.05, warmup_steps=0,
                          schedule="constant", first_moment=False, weight_decay=0.0)
    opt = Optimizer(cfg)
    target = jnp.asarray(np.random.default_rng(3).normal(size=(8, 16)), jnp.float32)
    params = {"w": jnp.zeros((8, 16))}
    state = opt.init(params)
    assert "m" not in state
    assert state["v_row"]["w"].shape == (8,)
    assert state["v_col"]["w"].shape == (16,)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 0.05


def test_lr_schedule_shapes():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                          schedule="cosine", min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in [0, 5, 10, 55, 100, 1000]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6  # linear warmup
    assert abs(lrs[2] - 1.0) < 1e-6  # peak
    assert lrs[2] > lrs[3] > lrs[4]  # cosine decay
    assert abs(lrs[4] - 0.1) < 1e-3  # floor
    assert abs(lrs[5] - 0.1) < 1e-3


def test_sgd_descends():
    cfg = OptimizerConfig(name="sgd", learning_rate=0.1, warmup_steps=0, schedule="constant")
    opt = Optimizer(cfg)
    params = {"w": jnp.asarray([5.0])}
    state = opt.init(params)
    for _ in range(100):
        g = {"w": 2 * params["w"]}
        params, state, _ = opt.update(g, state, params)
    assert abs(float(params["w"][0])) < 0.1

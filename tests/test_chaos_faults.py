"""Fault-injection chaos matrix (slow): faults must be unobservable.

A ContinuousStream consumes a deterministic MASS source while a seeded
:class:`repro.faults.FaultInjector` attacks the run at fixed *logical*
trigger points — a broker-node kill with a leader-election blackout, a
pilot crash recovered by the :class:`StageReconciler`, a slow consumer.
Every attacked run must fire the exact same windows with bit-identical
per-window aggregates as the fault-free inline baseline, with zero acked
records lost.

Determinism follows tests/test_chaos_rescale.py: logical event time, a
single topic partition, and a single keyed producer keep the per-record
ingest order identical across runs — replication (acks=all) preserves it
across a failover, and crash recovery replays it from the checkpoint cut.
"""
import time

import numpy as np
import pytest

from repro.core import PilotComputeService
from repro.elastic.metrics import MetricsBus
from repro.faults import FaultInjector, FaultSchedule
from repro.miniapps import RateStepScenario, SourceConfig
from repro.miniapps.mass import StreamSource
from repro.pipeline.runner import StageReconciler
from repro.streaming import TumblingWindow

N_MSGS = 1500
DT = 0.01  # logical seconds between events
WINDOW = 0.1
N_KEYS = 5
BASE_TS = 1000.0
EXPECTED_WINDOWS = (int(N_MSGS * DT / WINDOW) - 1) * N_KEYS


class _DeterministicSource(StreamSource):
    """Payload and event time are pure functions of the message index."""

    def make_message(self, rng, i):
        return np.array([i % N_KEYS, float(i) * 1.25], dtype=np.float64)

    def make_timestamp(self, rng, i):
        return BASE_TS + i * DT


class _BatchedDeterministicSource(_DeterministicSource):
    """Identical messages and event times, but sent through
    ``Producer.send_batch`` in fixed-size frames so a shm-transport run
    carries the whole stream over the ring — results must stay
    bit-identical to the per-message log baseline."""

    BATCH = 10

    def _produce(self, worker):
        from repro.broker.producer import Producer

        cfg = self.config
        rng = np.random.default_rng(cfg.seed + worker)
        rate = cfg.rate_msgs_per_s / cfg.n_producers if cfg.rate_msgs_per_s else None
        prod = Producer(self.cluster, cfg.topic, serializer=self.serializer,
                        rate_msgs_per_s=rate)
        self.producers.append(prod)
        quota = None if cfg.total_messages is None else (
            cfg.total_messages // cfg.n_producers)
        key = str(worker).encode() if cfg.keyed else None
        i = 0
        while not self._stop.is_set() and (quota is None or i < quota):
            if self.config.rate_msgs_per_s == 0:  # paused, not unthrottled
                self._stop.wait(0.01)
                continue
            n = self.BATCH if quota is None else min(self.BATCH, quota - i)
            prod.send_batch(
                [self.make_message(rng, i + j) for j in range(n)],
                key=key,
                timestamps=[self.make_timestamp(rng, i + j) for j in range(n)])
            i += n


def _window_fn(key, w, msgs):
    vals = np.array([m.value[1] for m in msgs], dtype=np.float64)
    # np.sum order-sensitivity is the point: any loss, duplication, or
    # reorder through a failover/recovery shows up in the low bits
    return key, w, float(np.sum(vals)), len(msgs)


def _run(schedule=None, *, seed=0, broker_nodes=1, replication_factor=1,
         executor="inline", checkpoint_every=0, reconcile=False,
         transport=None):
    """One full stream run under an optional fault schedule; returns
    (results, info) where info carries the observability counters the
    matrix asserts on."""
    svc = PilotComputeService(devices=list(range(10)),
                              heartbeat_interval=0.05, heartbeat_timeout=0.25)
    bus = MetricsBus()
    results: dict = {}
    injector = reconciler = None
    flink_pcd = {"number_of_nodes": 1, "cores_per_node": 2, "type": "flink"}
    try:
        kafka = svc.submit_pilot({"number_of_nodes": broker_nodes, "type": "kafka"})
        cluster = kafka.get_context()
        cluster.metrics = bus
        cluster.create_topic("chaos", 1, replication_factor=replication_factor)
        ring_name = None
        if transport == "shm":
            from repro.transport import ShmTransport

            shm = ShmTransport(slot_bytes=1 << 16, n_slots=64)
            cluster.attach_transport(shm)
            shm.mount("chaos")
            ring_name = shm.ring_for("chaos").name
        flink = svc.submit_pilot(flink_pcd)
        stream = flink.get_context().stream(
            cluster, "chaos", group="g",
            assigner=TumblingWindow(WINDOW),
            window_fn=_window_fn,
            key_fn=lambda m: int(m.value[0]),
            emit=lambda out: results.__setitem__((out[0], out[1]), (out[2], out[3])),
            metrics=bus,
            executor=executor,
            checkpoint_every=checkpoint_every,
            worker_options={"snapshot_every": 8} if executor == "mp" else None,
        )
        stream.start()
        if reconcile:
            reconciler = StageReconciler(svc, bus=bus)
            reconciler.manage("chaos", flink, stream, flink_pcd)
        src_cls = (_BatchedDeterministicSource if transport == "shm"
                   else _DeterministicSource)
        source = src_cls(cluster, SourceConfig(
            "chaos", total_messages=N_MSGS, n_producers=1, keyed=True, seed=7))
        scenario = RateStepScenario(
            source, [(0.4, 1000.0), (0.4, 4000.0), (0.4, 1800.0)], loop=True)
        source.start()
        scenario.start()
        if schedule is not None:
            injector = FaultInjector(schedule, seed=seed, cluster=cluster,
                                     topic="chaos", stream=stream,
                                     service=svc, pilot=flink).start()
        deadline = time.monotonic() + 90
        while stream.stats.fired_windows < EXPECTED_WINDOWS:
            assert time.monotonic() < deadline, (
                f"{stream.stats.fired_windows}/{EXPECTED_WINDOWS} windows fired; "
                f"events={injector.events if injector else []}; "
                f"recovery errors={reconciler.errors if reconciler else []}")
            time.sleep(0.02)
        scenario.stop()
        source.stop()
        if injector is not None:
            injector.stop()
        if reconciler is not None:
            reconciler.close()
        stream.stop()
        info = {
            "fired": stream.stats.fired_windows,
            "late": stream.stats.late_records,
            "failovers": cluster.failovers,
            "lost": cluster.lost_records,
            "prod_retries": sum(p.retries for p in source.producers),
            "cons_retries": stream.consumer.retries,
            "poll_delay": stream.consumer.injected_poll_delay,
            "recoveries": stream.recoveries,
            "stage_recoveries": reconciler.recoveries if reconciler else 0,
            "events": list(injector.events) if injector else [],
            "bus": bus,
            "ring_name": ring_name,
        }
    finally:
        svc.cancel()
    return results, info


def _assert_bit_identical(base_results, other_results, label):
    assert other_results.keys() == base_results.keys(), label
    for kw, (total, count) in base_results.items():
        o_total, o_count = other_results[kw]
        assert o_count == count, f"{label}: window {kw}: {o_count} != {count} records"
        assert o_total == total, f"{label}: window {kw}: aggregate drifted"


@pytest.fixture(scope="module")
def baseline():
    results, info = _run(None)
    assert info["late"] == 0 and info["fired"] == EXPECTED_WINDOWS
    return results


@pytest.mark.slow
@pytest.mark.parametrize("seed,at_records", [(1, 400), (2, 700), (3, 1000)])
def test_kill_broker_node_failover_is_unobservable(baseline, seed, at_records):
    """Leader loss mid-stream: a follower is promoted, producers/consumers
    retry through the election blackout, and no acked record is lost."""
    sched = FaultSchedule().kill_broker_node(
        at_records=at_records, node="leader", blackout=0.25)
    results, info = _run(sched, seed=seed, broker_nodes=3, replication_factor=2)
    assert info["failovers"] >= 1, info["events"]
    assert info["lost"] == 0, "replicated topic lost acked records"
    assert info["cons_retries"] >= 1, (
        "the blackout was never observed by the consumer")
    assert info["late"] == 0 and info["fired"] == EXPECTED_WINDOWS
    assert info["bus"].value("broker.failovers") >= 1
    _assert_bit_identical(baseline, results, f"broker kill seed={seed}")


@pytest.mark.slow
@pytest.mark.parametrize("seed,at_records", [(1, 350), (2, 650), (3, 950)])
def test_kill_pilot_recovers_via_reconciler(baseline, seed, at_records):
    """Pilot crash mid-stream: heartbeats go stale, the StageReconciler
    reprovisions and the stream resumes from its checkpoint spool with
    replayed firings suppressed — zero lost, zero duplicated."""
    sched = FaultSchedule().kill_pilot(at_records=at_records)
    results, info = _run(sched, seed=seed, checkpoint_every=100, reconcile=True)
    assert info["recoveries"] >= 1, info["events"]
    assert info["stage_recoveries"] >= 1
    assert info["late"] == 0 and info["fired"] == EXPECTED_WINDOWS
    assert info["bus"].value("pipeline.stage_recoveries", stage="chaos") >= 1
    _assert_bit_identical(baseline, results, f"pilot kill seed={seed}")


@pytest.mark.slow
@pytest.mark.parametrize("seed,at_records,delay", [
    (1, 300, 0.02), (2, 600, 0.03), (3, 900, 0.02)])
def test_slow_consumer_degrades_without_drift(baseline, seed, at_records, delay):
    """An injected poll delay slows processing; the fault expires on
    schedule and outputs stay identical (graceful degradation, no loss)."""
    sched = FaultSchedule().slow_consumer(
        at_records=at_records, delay=delay, until_records=at_records + 300)
    results, info = _run(sched, seed=seed)
    fired = [e for e in info["events"] if e.detail != "reverted"]
    reverted = [e for e in info["events"] if e.detail == "reverted"]
    assert len(fired) == 1 and len(reverted) == 1, info["events"]
    assert info["poll_delay"] == 0.0  # expiry actually reverted the knob
    assert info["late"] == 0 and info["fired"] == EXPECTED_WINDOWS
    _assert_bit_identical(baseline, results, f"slow consumer seed={seed}")


@pytest.mark.slow
def test_kill_pilot_shm_transport_recovers_and_cleans_ring(baseline):
    """Pilot crash while the stream rides the shared-memory ring: the
    replay floor (pinned at each checkpoint) must have held every slot the
    recovery replays — outputs stay bit-identical to the per-message log
    baseline with zero lost records — and pilot cancel must unlink the
    ring segment (no shm leak after crash + recover)."""
    sched = FaultSchedule().kill_pilot(at_records=600)
    results, info = _run(sched, seed=9, checkpoint_every=100, reconcile=True,
                         transport="shm")
    assert info["recoveries"] >= 1, info["events"]
    assert info["stage_recoveries"] >= 1
    assert info["lost"] == 0, "shm transport lost acked records"
    assert info["late"] == 0 and info["fired"] == EXPECTED_WINDOWS
    _assert_bit_identical(baseline, results, "shm pilot kill")
    from multiprocessing import shared_memory

    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(info["ring_name"])


@pytest.mark.slow
def test_kill_pilot_mp_executor_recovers(baseline):
    """Same pilot-crash recovery with the multiprocess executor: the crash
    SIGKILLs the worker processes; recover() restores the host store from
    the spool and reseeds a fresh worker fleet from it."""
    sched = FaultSchedule().kill_pilot(at_records=600)
    results, info = _run(sched, seed=5, executor="mp",
                         checkpoint_every=100, reconcile=True)
    assert info["recoveries"] >= 1, info["events"]
    assert info["late"] == 0 and info["fired"] == EXPECTED_WINDOWS
    _assert_bit_identical(baseline, results, "mp pilot kill")

"""End-to-end system behaviour: the paper's pipelines through the public API.

Covers: pilot provisioning (Listings 2-3), streams through the broker into
MASA processors (§5-6), runtime extension (Listing 4), interoperable CUs
(Listing 5), native contexts (Listing 6), and failure recovery.
"""
import time

import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core import PilotComputeDescription, PilotComputeService
from repro.miniapps import (
    KMeansClusterSource,
    LightsourceTemplateSource,
    ReconstructionApp,
    SourceConfig,
    StreamingKMeans,
    TokenSource,
    LMTrainApp,
)


@pytest.fixture
def svc():
    s = PilotComputeService()
    yield s
    s.cancel()


def test_streaming_kmeans_pipeline_converges(svc):
    cluster = svc.submit_pilot({"number_of_nodes": 2, "type": "kafka"}).get_context()
    cluster.create_topic("points", 4)
    ctx = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"}).get_context()
    src = KMeansClusterSource(
        cluster, SourceConfig("points", total_messages=16, n_producers=2),
        n_clusters=8, dim=3, points_per_msg=512,
    )
    app = StreamingKMeans(n_clusters=8, dim=3, decay=0.6)
    inertias = []

    def process(state, msgs):
        state = app.process(state, msgs)
        inertias.append(app.inertia)
        return state

    s = ctx.stream(cluster, "points", group="km", process_fn=process,
                   batch_interval=0.02, max_batch_records=2, backpressure=False)
    src.start(); s.start()
    s.await_batches(6, timeout=60)
    s.stop(); src.stop()
    assert inertias[-1] < inertias[0]
    assert s.state.shape == (8, 3)


def test_lightsource_reconstruction_pipeline(svc):
    cluster = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"}).get_context()
    cluster.create_topic("frames", 2)
    ctx = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"}).get_context()
    src = LightsourceTemplateSource(
        cluster, SourceConfig("frames", total_messages=3), n_angles=24, n_det=48,
    )
    app = ReconstructionApp("gridrec", n=48)
    s = ctx.stream(cluster, "frames", group="ls", process_fn=app.process, batch_interval=0.02)
    src.start(); s.start()
    s.await_batches(1, timeout=120)
    s.stop(); src.stop()
    assert s.state.shape == (48, 48)
    assert np.isfinite(np.asarray(s.state)).all()


def test_streaming_lm_training_loss_drops(svc):
    cfg = get_arch("smollm-135m").reduced(n_layers=2)
    cluster = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"}).get_context()
    cluster.create_topic("tokens", 2)
    ctx = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"}).get_context()
    src = TokenSource(cluster, SourceConfig("tokens", total_messages=6),
                      vocab_size=cfg.vocab_size, seq_len=64, seqs_per_msg=4)
    app = LMTrainApp(cfg, seqs_per_step=4, seq_len=64)
    s = ctx.stream(cluster, "tokens", group="lm", process_fn=app.process,
                   batch_interval=0.02, max_batch_records=1, backpressure=False)
    src.start(); s.start()
    s.await_batches(5, timeout=300)
    s.stop(); src.stop()
    assert app.losses[-1] < app.losses[0]


def test_runtime_extension_rebalances_lagging_pipeline(svc):
    """The paper's core capability: add resources to a running pipeline."""
    kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
    cluster = kafka.get_context()
    cluster.create_topic("work", 4)
    spark = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"})
    ctx = spark.get_context()

    from repro.broker import Producer

    prod = Producer(cluster, "work", serializer="npy")
    for i in range(30):
        prod.send(np.full((64,), i, np.float32))

    rescaled = []

    def process(state, msgs):
        time.sleep(0.01)
        return (state or 0) + len(msgs)

    s = ctx.stream(cluster, "work", group="g", process_fn=process,
                   batch_interval=0.02, max_batch_records=2, backpressure=False)
    s.on_rescale = lambda devices: rescaled.append(len(devices)) or s.state
    s.start()
    s.await_batches(2, timeout=20)
    ext = svc.submit_pilot(PilotComputeDescription(number_of_nodes=1, framework="spark",
                                                   parent=spark))
    deadline = time.monotonic() + 30
    while sum(s.lag().values()) > 0 and time.monotonic() < deadline:
        time.sleep(0.05)
    s.stop()
    assert rescaled, "engine did not observe the rescale"
    assert sum(s.lag().values()) == 0
    assert s.state == 30


def test_interoperable_cu_across_engines(svc):
    """Listing 5: the same CU payload runs on taskpool and microbatch engines."""
    def compute(x):
        return x * x

    for framework in ("dask", "spark"):
        pilot = svc.submit_pilot({"number_of_nodes": 1, "type": framework})
        cu = pilot.submit(compute, 9)
        assert cu.wait(10) == 81

"""Property suite (hypothesis) for the serving page allocator + traces.

The allocator invariants that make paged serving safe to run unattended:

* page 0 (the scratch page padded tables point at) is never allocated and
  never enters the free list;
* no page is ever owned by two sequences or simultaneously free and owned;
* pages are conserved across ANY sequence of alloc/ensure/release/reset —
  never leaked, never invented;
* ``alloc`` is atomic: a refused request changes nothing;
* release returns exactly what was allocated, and a full
  alloc-all/release-all cycle restores full capacity.

Plus: the heavy-tail trace generator is a pure function of its config —
byte-identical replays are what make the lockstep-vs-continuous benchmark a
controlled comparison (engine-level replay determinism is the crash test in
``tests/test_serving.py``).

``tests/test_serving.py`` holds the always-run engine-level suite.
"""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.serving import PageAllocError, PagePool, TraceConfig, heavy_tail_trace

# one op of the allocator fuzz program: (kind, seq id, token count)
_ops = st.lists(
    st.tuples(
        st.sampled_from(["alloc", "ensure", "release", "reset"]),
        st.integers(0, 7),
        st.integers(0, 64),
    ),
    max_size=60,
)


@given(st.integers(2, 40), st.integers(1, 16), _ops)
@settings(max_examples=120, deadline=None)
def test_pool_invariants_hold_under_any_program(n_pages, page_size, ops):
    pool = PagePool(n_pages, page_size)
    for kind, seq, n_tokens in ops:
        free_before = pool.free_pages
        owned_before = len(pool.owned(seq))
        if kind == "alloc":
            n = pool.pages_for(n_tokens)
            try:
                ok = pool.alloc(seq, n)
            except PageAllocError:
                assert n > pool.capacity_pages
                ok = None
            if ok is False:  # refused: atomic, nothing changed
                assert pool.free_pages == free_before
                assert len(pool.owned(seq)) == owned_before
            elif ok:
                assert len(pool.owned(seq)) == owned_before + n
        elif kind == "ensure":
            try:
                ok = pool.ensure(seq, n_tokens)
            except PageAllocError:
                ok = None
            if ok:
                assert pool.capacity_tokens(seq) >= n_tokens
            elif ok is False:
                assert pool.free_pages == free_before
        elif kind == "release":
            freed = pool.release(seq)
            assert freed == owned_before
            assert pool.free_pages == free_before + freed
            assert pool.owned(seq) == []
        else:
            pool.reset()
            assert pool.free_pages == pool.capacity_pages
            assert pool.sequences() == set()
        pool.check_invariants()
    # full drain restores full capacity
    for seq in list(pool.sequences()):
        pool.release(seq)
    assert pool.free_pages == pool.capacity_pages
    pool.check_invariants()


@given(st.integers(2, 30), st.integers(1, 8), st.integers(0, 6))
@settings(max_examples=60, deadline=None)
def test_pool_alloc_all_then_release_all_roundtrips(n_pages, page_size, n_seqs):
    pool = PagePool(n_pages, page_size)
    per = pool.capacity_pages // max(n_seqs, 1)
    placed = []
    for s in range(n_seqs):
        if per and pool.alloc(s, per):
            placed.append(s)
    assert pool.used_pages == per * len(placed)
    # LIFO determinism: the same program hands out the same pages
    pool2 = PagePool(n_pages, page_size)
    for s in placed:
        assert pool2.alloc(s, per)
        assert pool2.owned(s) == pool.owned(s)
    for s in placed:
        pool.release(s)
    assert pool.free_pages == pool.capacity_pages
    pool.check_invariants()


@given(st.integers(0, 2**32 - 1), st.integers(1, 40))
@settings(max_examples=40, deadline=None)
def test_trace_replay_is_byte_identical(seed, n):
    cfg = TraceConfig(n_requests=n, seed=seed)
    a, b = heavy_tail_trace(cfg), heavy_tail_trace(cfg)
    assert a == b
    for r in a:
        assert 1 <= r.prompt_len <= cfg.max_prompt
        assert 1 <= r.out_tokens <= cfg.max_output
        assert all(1 <= t < cfg.vocab for t in r.prompt)
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[0] > 0.0


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_trace_overrides_equal_explicit_config(seed):
    assert heavy_tail_trace(TraceConfig(), seed=seed, n_requests=9) == \
        heavy_tail_trace(TraceConfig(seed=seed, n_requests=9))

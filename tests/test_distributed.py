"""Multi-device integration (8 forced host devices, subprocess):

* vocab-parallel CE / embedding == dense references
* sharded flash attention == naive attention (values AND grads)
* a small arch train step lowers, compiles and runs on a (2,4) mesh
* cross-mesh checkpoint restore (elastic restart)
"""
import pytest


def test_vocab_parallel_ce_and_embed_match_dense(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.runtime.sharding import ShardingRules, activation_rules
from repro.runtime.losses import vocab_parallel_cross_entropy, vocab_parallel_embed

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(mesh=mesh, batch_axes=("data",), kind="train")
B, S, D, V = 4, 32, 16, 64
ks = jax.random.split(jax.random.key(0), 3)
x = jax.random.normal(ks[0], (B, S, D))
head = jax.random.normal(ks[1], (V, D)) * 0.1
targets = jax.random.randint(ks[2], (B, S), 0, V)
mask = jnp.ones((B, S), jnp.float32)

def dense(x, head, t, m):
    logits = (x @ head.T).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, t[..., None], -1)[..., 0]
    return (((lse - picked) * m).sum(), m.sum())

with mesh:
    tot_d, cnt_d = dense(x, head, targets, mask)
    f = jax.jit(lambda *a: vocab_parallel_cross_entropy(*a, rules, chunk=8))
    tot_p, cnt_p = f(x, head, targets, mask)
np.testing.assert_allclose(float(tot_p), float(tot_d), rtol=1e-5)
assert float(cnt_p) == float(cnt_d)

# gradients too
gd = jax.grad(lambda x: dense(x, head, targets, mask)[0])(x)
with mesh:
    gp = jax.jit(jax.grad(lambda x: vocab_parallel_cross_entropy(x, head, targets, mask, rules, chunk=8)[0]))(x)
np.testing.assert_allclose(np.asarray(gp), np.asarray(gd), atol=1e-4)

# embedding
tokens = jax.random.randint(jax.random.key(9), (B, S), 0, V)
with mesh:
    e = jax.jit(lambda t, w: vocab_parallel_embed(t, w, rules))(tokens, head)
np.testing.assert_allclose(np.asarray(e), np.asarray(head[tokens]), atol=1e-6)
print("CE+EMBED OK")
""",
        n_devices=8,
    )


def test_sharded_attention_matches_naive(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.models.attention import naive_attention
from repro.runtime.sharding import ShardingRules, activation_rules
from repro.runtime.sharded_attention import sharded_attention

mesh = jax.make_mesh((2, 4), ("data", "model"))
B, S, H, KV, hd = 4, 64, 6, 3, 16
ks = jax.random.split(jax.random.key(0), 3)
q = jax.random.normal(ks[0], (B, S, H, hd))
k = jax.random.normal(ks[1], (B, S, KV, hd))
v = jax.random.normal(ks[2], (B, S, KV, hd))

for kind, impl in (("prefill", "allgather"), ("train", "allgather"), ("train", "flash")):
    rules = ShardingRules(mesh=mesh, batch_axes=("data",), kind=kind)
    with mesh:
        out = jax.jit(lambda q, k, v: sharded_attention(q, k, v, rules, causal=True, block_kv=16, impl=impl))(q, k, v)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-5)
    print(kind, impl, "OK")

# train grads through the sharded path == naive grads
rules = ShardingRules(mesh=mesh, batch_axes=("data",), kind="train")
def loss_sharded(q, k, v):
    with mesh:
        return jnp.sum(jnp.sin(sharded_attention(q, k, v, rules, causal=True, block_kv=16, impl="flash")))
def loss_naive(q, k, v):
    return jnp.sum(jnp.sin(naive_attention(q, k, v, causal=True)))
with mesh:
    g1 = jax.jit(jax.grad(loss_sharded, argnums=(0, 1, 2)))(q, k, v)
g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
for a, b in zip(g1, g2):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-5)
print("GRADS OK")
""",
        n_devices=8,
    )


def test_small_mesh_train_step_runs(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch
from repro.models import build_model
from repro.runtime.steps import build_train_step

cfg = get_arch("qwen3-14b").reduced(d_model=64, d_ff=128, n_layers=2, vocab_size=256,
                                    n_heads=4, n_kv_heads=2, head_dim=16)
model = build_model(cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = ShapeConfig("t", 64, 4, "train")
bundle = build_train_step(model, mesh, shape, donate=False)
params = model.init(jax.random.key(0))
from repro.runtime.optimizer import Optimizer, OptimizerConfig
opt = Optimizer(OptimizerConfig(name=cfg.optimizer, moment_dtype=cfg.moment_dtype))
opt_state = opt.init(params)
batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 64), 0, 256)}
with mesh:
    params = jax.device_put(params, bundle.in_shardings[0])
    opt_state = jax.device_put(opt_state, bundle.in_shardings[1])
    losses = []
    for i in range(3):
        params, opt_state, metrics = bundle.fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
assert losses[-1] < losses[0], losses
assert all(np.isfinite(losses))
print("TRAIN STEP OK", [round(l, 3) for l in losses])
""",
        n_devices=8,
    )


def test_elastic_checkpoint_cross_mesh_restore(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import CheckpointManager

mesh8 = jax.make_mesh((8,), ("model",))
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
state = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8), NamedSharding(mesh8, P("model")))}
d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mgr.save(1, state)
target = NamedSharding(mesh2, P(("data", "model"), None))
restored, _ = mgr.restore(state, shardings={"w": target})
np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(64.0).reshape(8, 8))
assert restored["w"].sharding == target
print("ELASTIC RESTORE OK")
""",
        n_devices=8,
    )


def test_ring_attention_matches_naive(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.models.attention import naive_attention
from repro.runtime.sharding import ShardingRules
from repro.runtime.ring_attention import ring_attention_shmap

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(mesh=mesh, batch_axes=("data",), kind="prefill")
B, S, H, KV, hd = 4, 64, 6, 3, 16
ks = jax.random.split(jax.random.key(3), 3)
q = jax.random.normal(ks[0], (B, S, H, hd))
k = jax.random.normal(ks[1], (B, S, KV, hd))
v = jax.random.normal(ks[2], (B, S, KV, hd))
for causal in (True, False):
    with mesh:
        out = jax.jit(lambda q, k, v: ring_attention_shmap(
            q, k, v, rules, causal=causal, block_kv=16, scale=hd**-0.5))(q, k, v)
    ref = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-5)
    print("ring causal=", causal, "OK")
# the ring schedule must lower to collective-permutes, not all-gathers
with mesh:
    txt = jax.jit(lambda q, k, v: ring_attention_shmap(
        q, k, v, rules, causal=True, block_kv=16, scale=hd**-0.5)).lower(q, k, v).compile().as_text()
assert "collective-permute" in txt
print("RING OK")
""",
        n_devices=8,
    )

"""repro.serving — continuous batching over the paged KV cache.

The load-bearing claims (docs/serving.md):

* the paged gather/scatter decode produces greedy tokens **bit-identical**
  to the dense prefill + per-token decode path, for any interleaving of
  joins and exits;
* compile count is bounded by the shape buckets, not the trace;
* admission never deadlocks (lifetime reservation) and never loses or
  duplicates a request — including across a pilot crash mid-trace;
* the Pallas decode kernel (interpret mode on CPU) slots into the same
  scheduler and produces the same tokens;
* ``LMServeApp(mode="continuous")`` is a drop-in for the lockstep server.

``tests/test_serving_props.py`` holds the hypothesis property suite for the
page allocator and trace determinism.
"""
import time
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models import build_model
from repro.serving import (
    ADMIT,
    QUEUE,
    REJECT,
    AdmissionController,
    ContinuousBatcher,
    PageAllocError,
    PagedKVCache,
    PagePool,
    Request,
    TraceConfig,
    heavy_tail_trace,
    trace_summary,
)


@dataclass
class Msg:
    value: Any
    timestamp: float = 0.0


@pytest.fixture(scope="module")
def served():
    """(model, params) on the reduced config — shared, params never mutated."""
    cfg = get_arch("smollm-135m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


SMALL_TRACE = dict(n_requests=10, seed=3, rate=64.0, prompt_median=10,
                   max_prompt=40, out_median=5, out_sigma=0.5, max_output=10)


def run_trace(batcher, trace):
    now = 0.0
    verdicts = []
    for r in trace:
        now = max(now, r.arrival)
        verdicts.append(batcher.submit(r, now))
        now += batcher.step(now)
    batcher.drain(now)
    return verdicts


def dense_greedy(model, params, req):
    """Reference: dense prefill + per-token decode, greedy."""
    toks = jnp.asarray(np.array(req.prompt, np.int32)[None])
    logits, cache = jax.jit(model.prefill)(params, {"tokens": toks})
    seq = [int(jnp.argmax(logits[:, -1], -1)[0])]
    cache = jax.tree.map(
        lambda c: jnp.pad(c, [(0, 0)] * 2 + [(0, req.out_tokens)] + [(0, 0)] * (c.ndim - 3))
        if c.ndim >= 4 else c, cache)
    dec = jax.jit(model.decode)
    pos = req.prompt_len - 1
    for _ in range(req.out_tokens - 1):
        pos += 1
        lg, cache = dec(params, cache, {
            "tokens": jnp.asarray([[seq[-1]]], jnp.int32),
            "positions": jnp.asarray([pos], jnp.int32)})
        seq.append(int(jnp.argmax(lg[:, -1], -1)[0]))
    return seq


# ---------------------------------------------------------------------------
# page pool (always-run mirror of the property suite)
# ---------------------------------------------------------------------------


def test_page_pool_alloc_release_conservation():
    pool = PagePool(9, 4)
    assert pool.capacity_pages == 8  # page 0 reserved
    assert pool.alloc("a", 3) and pool.alloc("b", 5)
    assert pool.free_pages == 0
    assert not pool.alloc("c", 1), "over-capacity alloc must fail atomically"
    assert "c" not in pool.sequences()  # failed alloc leaves no owner behind
    pool.check_invariants()
    assert pool.release("a") == 3
    assert pool.alloc("c", 3)
    assert pool.release("b") == 5 and pool.release("c") == 3
    assert pool.free_pages == pool.capacity_pages
    pool.check_invariants()


def test_page_pool_rejects_impossible_request():
    pool = PagePool(4, 2)
    with pytest.raises(PageAllocError):
        pool.alloc("x", 99)
    pool.check_invariants()


def test_page_pool_ensure_grows_to_token_count():
    pool = PagePool(8, 4)
    assert pool.ensure("s", 10)  # 3 pages
    assert pool.capacity_tokens("s") == 12
    assert pool.ensure("s", 12)  # no-op
    assert len(pool.owned("s")) == 3
    assert pool.ensure("s", 13)
    assert len(pool.owned("s")) == 4
    pool.check_invariants()


def test_paged_cache_table_pads_with_scratch_and_truncates():
    cache = PagedKVCache(1, 1, 4, n_pages=8, page_size=2)
    assert cache.admit("a", 6)  # 3 pages
    t = cache.table(["a"], 4)
    assert t.shape == (1, 4) and t[0, 3] == 0 and (t[0, :3] > 0).all()
    with pytest.raises(ValueError):
        cache.table(["a"], 2)
    t = cache.table(["a"], 2, truncate=True)
    assert (t[0] == cache.pool.owned("a")[:2]).all()
    t = cache.table(["a"], 4, rows=3)
    assert t.shape == (3, 4) and (t[1:] == 0).all()


# ---------------------------------------------------------------------------
# admission
# ---------------------------------------------------------------------------


def test_admission_rate_limit_rejects_at_the_door():
    pool = PagePool(64, 16)
    adm = AdmissionController(pool, rate=10.0, burst=20.0)
    assert adm.offer(16, 0.0, queue_depth=0) == ADMIT
    assert adm.offer(16, 0.0, queue_depth=0) == REJECT  # bucket empty
    assert adm.offer(16, 2.0, queue_depth=0) == ADMIT  # refilled
    assert adm.stats.rejected_rate == 1


def test_admission_queues_then_rejects_when_full():
    pool = PagePool(3, 4)  # 2 usable pages = 8 tokens
    adm = AdmissionController(pool, max_queue=2)
    assert adm.offer(8, 0.0, queue_depth=0) == ADMIT
    pool.alloc("a", 2)
    assert adm.offer(4, 0.0, queue_depth=0) == QUEUE  # no pages left
    assert adm.offer(4, 0.0, queue_depth=1) == QUEUE
    assert adm.offer(4, 0.0, queue_depth=2) == REJECT
    assert adm.stats.as_dict()["rejected_queue_full"] == 1


def test_admission_fifo_no_bypass():
    """A small arrival behind a queued big one must queue, not jump ahead."""
    pool = PagePool(5, 4)
    adm = AdmissionController(pool)
    pool.alloc("live", 3)  # 1 page free
    assert adm.offer(8, 0.0, queue_depth=0) == QUEUE  # needs 2
    assert adm.offer(2, 0.0, queue_depth=1) == QUEUE  # would fit, but FIFO


def test_admission_headroom_reserve():
    pool = PagePool(5, 4)
    adm = AdmissionController(pool, headroom_pages=2)
    assert adm.can_place(8)  # 2 <= 4 - 2
    assert not adm.can_place(9)  # 3 > 4 - 2


# ---------------------------------------------------------------------------
# trace generator
# ---------------------------------------------------------------------------


def test_trace_is_seed_deterministic_and_bounded():
    a = heavy_tail_trace(TraceConfig(seed=7))
    b = heavy_tail_trace(TraceConfig(seed=7))
    assert a == b
    c = heavy_tail_trace(TraceConfig(seed=8))
    assert a != c
    cfg = TraceConfig()
    for r in a:
        assert 1 <= r.prompt_len <= cfg.max_prompt
        assert 1 <= r.out_tokens <= cfg.max_output
        assert all(1 <= t < cfg.vocab for t in r.prompt)  # 0 reserved for EOS
    assert [r.arrival for r in a] == sorted(r.arrival for r in a)
    s = trace_summary(a)
    assert s["n_requests"] == len(a) and s["prompt_p99"] >= s["prompt_p50"]


# ---------------------------------------------------------------------------
# continuous batcher: end-to-end, equivalence, compile bounds
# ---------------------------------------------------------------------------


def test_batcher_serves_trace_and_releases_every_page(served):
    cfg, model, params = served
    trace = heavy_tail_trace(TraceConfig(**SMALL_TRACE, vocab=cfg.vocab_size))
    b = ContinuousBatcher(model, n_pages=64, page_size=8, max_queue=32)
    b.params = params
    verdicts = run_trace(b, trace)
    assert REJECT not in verdicts
    assert set(b.results) == {r.rid for r in trace}
    for r in trace:
        assert len(b.results[r.rid]["tokens"]) == r.out_tokens
        assert b.results[r.rid]["first_token"] >= r.arrival
        assert b.results[r.rid]["finish"] >= b.results[r.rid]["first_token"]
    assert b.cache.pool.used_pages == 0, "finished sequences must free pages"
    b.cache.pool.check_invariants()
    assert b.idle and not b._journal


def test_batcher_tokens_bit_identical_to_dense_path(served):
    """The tentpole equivalence claim: in-flight joins/exits change *when*
    work happens, never *what* each sequence computes."""
    cfg, model, params = served
    trace = heavy_tail_trace(TraceConfig(**SMALL_TRACE, vocab=cfg.vocab_size))
    b = ContinuousBatcher(model, n_pages=64, page_size=8, max_queue=32)
    b.params = params
    run_trace(b, trace)
    for r in trace:
        assert list(b.results[r.rid]["tokens"]) == dense_greedy(model, params, r), r.rid


def test_batcher_compile_count_bounded_by_buckets(served):
    cfg, model, params = served
    trace = heavy_tail_trace(TraceConfig(**{**SMALL_TRACE, "n_requests": 24},
                                         vocab=cfg.vocab_size))
    b = ContinuousBatcher(model, n_pages=64, page_size=8, max_queue=64)
    b.params = params
    run_trace(b, trace)
    n_prompt_buckets = len({b.prompt_buckets.fit(r.prompt_len) for r in trace})
    # prefill shapes: (joiner-rows bucket) x (prompt bucket) combinations
    assert 1 <= b.prefill_compiles <= len(b.batch_buckets.sizes) * n_prompt_buckets
    # decode shapes: (batch bucket) x (max-pages bucket) combinations
    assert b.decode_compiles <= len(b.batch_buckets.sizes) * len(b.pages_buckets.sizes)
    pre, dec = b.prefill_compiles, b.decode_compiles
    b.reset()
    run_trace(b, trace)  # same trace -> zero new compiles
    assert (b.prefill_compiles, b.decode_compiles) == (pre, dec)


def test_batcher_eos_exits_early_and_frees_pages(served):
    cfg, model, params = served
    b = ContinuousBatcher(model, n_pages=32, page_size=8)
    b.params = params
    # find what token the model emits first, then use it as the EOS id so
    # the sequence stops at 1 generated token despite a 6-token budget
    probe = Request(0, 0.0, (5, 6, 7), 6)
    b.submit(probe, 0.0)
    b.drain(0.0)
    eos = b.results[0]["tokens"][0]
    b2 = ContinuousBatcher(model, n_pages=32, page_size=8, eos_id=int(eos))
    b2.params = params
    b2.submit(Request(1, 0.0, (5, 6, 7), 6), 0.0)
    b2.drain(0.0)
    assert b2.results[1]["tokens"] == (eos,)
    assert b2.cache.pool.used_pages == 0


def test_batcher_queue_admits_as_pages_free(served):
    """A pool sized for ~1 request at a time still serves the whole trace:
    arrivals queue and join as predecessors finish."""
    cfg, model, params = served
    trace = heavy_tail_trace(TraceConfig(
        n_requests=6, seed=5, rate=512.0, prompt_median=8, max_prompt=16,
        out_median=4, max_output=6, vocab=cfg.vocab_size))
    b = ContinuousBatcher(model, n_pages=7, page_size=8, max_queue=16)
    b.params = params
    verdicts = run_trace(b, trace)
    assert QUEUE in verdicts, "pool this small must force queueing"
    assert REJECT not in verdicts
    assert set(b.results) == {r.rid for r in trace}
    for r in trace:
        assert list(b.results[r.rid]["tokens"]) == dense_greedy(model, params, r)


def test_batcher_rejects_never_ghost(served):
    """Rate-rejected requests are dropped at the door: no journal entry, no
    result, and the rest of the trace is unaffected."""
    cfg, model, params = served
    trace = heavy_tail_trace(TraceConfig(**SMALL_TRACE, vocab=cfg.vocab_size))
    b = ContinuousBatcher(model, n_pages=64, page_size=8, rate=60.0, burst=60.0)
    b.params = params
    verdicts = run_trace(b, trace)
    assert REJECT in verdicts, "tight rate must shed something"
    rejected = {r.rid for r, v in zip(trace, verdicts) if v == REJECT}
    assert rejected.isdisjoint(b.results)
    assert set(b.results) == {r.rid for r in trace} - rejected
    assert b.admission.stats.rejected_rate == len(rejected)


# ---------------------------------------------------------------------------
# chaos: pilot crash mid-trace
# ---------------------------------------------------------------------------


def test_crash_mid_trace_recovers_no_dupes_no_losses(served):
    cfg, model, params = served
    trace = heavy_tail_trace(TraceConfig(**SMALL_TRACE, vocab=cfg.vocab_size))
    ref = ContinuousBatcher(model, n_pages=64, page_size=8, max_queue=32)
    ref.params = params
    run_trace(ref, trace)

    for crash_at in (0, len(trace) // 2, len(trace) - 1):
        b = ContinuousBatcher(model, n_pages=64, page_size=8, max_queue=32)
        b.params = params
        now = 0.0
        for i, r in enumerate(trace):
            now = max(now, r.arrival)
            b.submit(r, now)
            now += b.step(now)
            if i == crash_at:
                b.crash()
                assert b.cache.pool.used_pages == 0
                b.recover()
        b.drain(now)
        assert set(b.results) == set(ref.results), crash_at
        for rid in ref.results:
            assert b.results[rid]["tokens"] == ref.results[rid]["tokens"], (crash_at, rid)
        b.cache.pool.check_invariants()


# ---------------------------------------------------------------------------
# Pallas decode kernel through the scheduler (interpret mode on CPU)
# ---------------------------------------------------------------------------


def test_kernel_decode_matches_jnp_through_batcher(served):
    cfg, model, params = served
    trace = heavy_tail_trace(TraceConfig(**{**SMALL_TRACE, "n_requests": 6},
                                         vocab=cfg.vocab_size))
    ref = ContinuousBatcher(model, n_pages=64, page_size=8)
    ref.params = params
    run_trace(ref, trace)
    ker = ContinuousBatcher(model, n_pages=64, page_size=8,
                            use_kernel=True, interpret=True)
    ker.params = params
    run_trace(ker, trace)
    for r in trace:
        assert ker.results[r.rid]["tokens"] == ref.results[r.rid]["tokens"], r.rid


# ---------------------------------------------------------------------------
# LMServeApp drop-in
# ---------------------------------------------------------------------------


def _msgs(cfg, rng, n_msgs=2, batch=2, prompt_len=12):
    return [Msg(rng.integers(1, cfg.vocab_size, size=(batch, prompt_len)).astype(np.int32))
            for _ in range(n_msgs)]


def test_lm_serve_continuous_matches_lockstep(served):
    from repro.miniapps import LMServeApp

    cfg, model, params = served
    rng = np.random.default_rng(11)
    msgs = _msgs(cfg, rng)
    lock = LMServeApp(cfg, prompt_len=12, gen_tokens=5, batch=2)
    cont = LMServeApp(cfg, prompt_len=12, gen_tokens=5, batch=2,
                      mode="continuous", n_pages=64, page_size=8)
    p_lock = lock.model.init(jax.random.key(0))
    p_cont = cont.model.init(jax.random.key(0))
    a = lock.generate_tokens(p_lock, msgs)
    c = cont.generate_tokens(p_cont, msgs)
    assert a.shape == c.shape == (4, 5)
    np.testing.assert_array_equal(a, c)


def test_lm_serve_prefill_compiles_once_per_row_bucket(served):
    """Satellite: the in-jit cache growth must not recompile per batch."""
    from repro.miniapps import LMServeApp

    cfg, model, params = served
    rng = np.random.default_rng(12)
    app = LMServeApp(cfg, prompt_len=12, gen_tokens=4, batch=2)
    p = app.model.init(jax.random.key(0))
    app.generate_tokens(p, _msgs(cfg, rng))
    assert app.prefill_compiles == 1
    for _ in range(3):  # same row bucket -> no new compiles
        app.generate_tokens(p, _msgs(cfg, rng))
    assert app.prefill_compiles == 1
    assert app.compiles == 1  # fused scan decode likewise


def test_lm_serve_continuous_process_counts_and_gauges(served):
    from repro.elastic.metrics import MetricsBus
    from repro.miniapps import LMServeApp

    cfg, model, params = served
    bus = MetricsBus()
    app = LMServeApp(cfg, prompt_len=12, gen_tokens=4, batch=2,
                     mode="continuous", n_pages=64, page_size=8, metrics=bus)
    p = app.model.init(jax.random.key(0))
    rng = np.random.default_rng(13)
    app.process(p, _msgs(cfg, rng))
    app.sync()
    assert app.stats.batches == 1 and app.stats.items == 4 * 4
    assert bus.latest("serving.page_utilization") is not None
    assert bus.latest("serving.free_pages").value > 0


def test_batcher_decode_quantum_bit_identical(served):
    """quantum>1 decodes q tokens per dispatch (gather-once scan + masked
    scatter); greedy decode is prefix-stable, so results must not change."""
    cfg, model, params = served
    trace = heavy_tail_trace(TraceConfig(**SMALL_TRACE, vocab=cfg.vocab_size))
    ref = ContinuousBatcher(model, n_pages=64, page_size=8, max_queue=32)
    ref.params = params
    run_trace(ref, trace)
    q = ContinuousBatcher(model, n_pages=64, page_size=8, max_queue=32,
                          decode_quantum=4)
    q.params = params
    run_trace(q, trace)
    for r in trace:
        assert list(q.results[r.rid]["tokens"]) == list(ref.results[r.rid]["tokens"]), r.rid
        assert len(q.results[r.rid]["tokens"]) == r.out_tokens  # budget mask holds
    assert q.cache.pool.used_pages == 0


def test_batcher_burst_stacked_prefill_matches_dense(served):
    """All requests submitted at t=0: joiners group into multi-row prefill
    dispatches (one per prompt bucket), which must scatter every row's pages."""
    cfg, model, params = served
    trace = heavy_tail_trace(TraceConfig(**{**SMALL_TRACE, "rate": 1e9},
                                         vocab=cfg.vocab_size))
    b = ContinuousBatcher(model, n_pages=64, page_size=8, max_queue=32)
    b.params = params
    now = 0.0
    for r in trace:
        assert b.submit(r, now) is not REJECT
    b.drain(now)
    n_prompt_buckets = len({b.prompt_buckets.fit(r.prompt_len) for r in trace})
    # the burst admits together -> at most one dispatch per (rows, prompt) bucket
    assert b.prefill_compiles <= len(b.batch_buckets.sizes) * n_prompt_buckets
    for r in trace:
        assert list(b.results[r.rid]["tokens"]) == dense_greedy(model, params, r), r.rid


def test_batcher_warmup_precompiles_all_buckets(served):
    """After warmup() bounded by the trace's shape envelope, a replay performs
    zero additional compiles -- the benchmark's no-leak guarantee."""
    cfg, model, params = served
    trace = heavy_tail_trace(TraceConfig(**SMALL_TRACE, vocab=cfg.vocab_size))
    b = ContinuousBatcher(model, n_pages=64, page_size=8, max_queue=32)
    b.params = params
    compiled = b.warmup(max_prompt=max(r.prompt_len for r in trace),
                        max_tokens=max(r.prompt_len + r.out_tokens for r in trace))
    assert compiled > 0
    pre, dec = b.prefill_compiles, b.decode_compiles
    run_trace(b, trace)
    assert (b.prefill_compiles, b.decode_compiles) == (pre, dec), \
        "trace visited a shape the warmup sweep missed"

"""Fig. 8 analog: dynamic resourcing under a producer rate step.

A MASS source doubles its rate mid-run; the ElasticController grows the
processing pilot with an extension pilot, lag drains, the rate drops, and
the controller shrinks back. Emits the full timeline (lag, devices,
throughput vs. time) as JSON next to this file and returns summary rows
for ``benchmarks/run.py``:

* scale-up reaction time (high-water crossing -> extension pilot running)
* lag recovery time (extension running -> lag back under high water)
* peak lag and device trajectory
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import PilotComputeService
from repro.elastic import (
    ElasticConfig,
    ElasticController,
    MetricsBus,
    ThresholdHysteresisPolicy,
    timeline,
)
from repro.miniapps import RateStepScenario, SourceConfig, StreamSource

TIMELINE_PATH = os.path.join(os.path.dirname(__file__), "elasticity_timeline.json")

HIGH_LAG, LOW_LAG = 80.0, 15.0
BASE_DEVICES, STEP_DEVICES = 2, 2
PER_MSG = 0.01  # seconds of processing per message per device


class _PointSource(StreamSource):
    def make_message(self, rng, i):
        return rng.normal(size=(8,))


def _scenario(duration_scale: float = 1.0):
    svc = PilotComputeService(devices=list(range(8)))
    bus = MetricsBus()
    kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
    cluster = kafka.get_context()
    cluster.create_topic("elastic_bench", 4)
    engine = svc.submit_pilot(
        {"number_of_nodes": 1, "cores_per_node": BASE_DEVICES, "type": "spark"})
    ctx = engine.get_context()
    capacity = {"n": BASE_DEVICES}

    def process(state, msgs):
        # device-proportional cost: the data-parallel resharding contract
        time.sleep(len(msgs) * PER_MSG / max(capacity["n"], 1))
        return (state or 0) + len(msgs)

    stream = ctx.stream(cluster, "elastic_bench", group="g", process_fn=process,
                        batch_interval=0.05, max_batch_records=32,
                        backpressure=False, metrics=bus)

    def on_rescale(devices):
        capacity["n"] = max(len(devices), 1)
        return stream.state

    stream.on_rescale = on_rescale

    src = _PointSource(cluster, SourceConfig("elastic_bench", rate_msgs_per_s=60))
    ctl = ElasticController(
        svc, engine, bus,
        ThresholdHysteresisPolicy(high_lag=HIGH_LAG, low_lag=LOW_LAG,
                                  up_stable=2, down_stable=3),
        config=ElasticConfig(interval=0.1, min_devices=BASE_DEVICES, max_devices=6,
                             devices_per_step=STEP_DEVICES, cooldown=1.2),
        lag_probe=lambda: sum(stream.lag().values()),
    )
    steps = [(1.0 * duration_scale, 60), (5.0 * duration_scale, 300),
             (5.0 * duration_scale, 40)]
    scenario = RateStepScenario(src, steps)
    stream.start()
    src.start()
    ctl.start()
    t0 = time.monotonic()
    scenario.start()
    try:
        deadline = t0 + sum(d for d, _ in steps) + 15.0
        while time.monotonic() < deadline:
            if scenario.finished and ctl.devices == BASE_DEVICES:
                break
            time.sleep(0.1)
    finally:
        scenario.stop()
        src.stop()
        ctl.shutdown()
        stream.stop()
        svc.cancel()
    return bus, ctl, scenario, t0


def run(duration_scale: float = 1.0):
    bus, ctl, scenario, t0 = _scenario(duration_scale)

    tl = timeline(bus, ctl.events, t0=t0)
    tl["rate_steps"] = [[round(t - t0, 4), r] for t, r in scenario.transitions]
    with open(TIMELINE_PATH, "w") as f:
        json.dump(tl, f, indent=1)

    lag_series = bus.series("elastic.lag")
    rows = [("elasticity_timeline", 0.0, f"json={os.path.basename(TIMELINE_PATH)};"
             f"points={sum(len(v) for v in tl['series'].values())}")]
    ups = ctl.events.of("scale_up")
    downs = ctl.events.of("scale_down")
    if ups:
        up = ups[0]
        # reaction = scale-up minus the crossing that *started* the episode
        # the policy reacted to: the last low->high transition at or before
        # up.t. The first crossing ever may belong to an earlier excursion
        # that drained on its own, which would overstate the reaction time.
        episode_start = None
        above = False
        for t, v in lag_series:
            if t > up.t:
                break
            if v >= HIGH_LAG and not above:
                episode_start = t
            above = v >= HIGH_LAG
        react = up.t - episode_start if episode_start is not None else float("nan")
        rows.append(("elasticity_scale_up_reaction", react * 1e6,
                     f"devices={up.devices_before}->{up.devices_after}"))
        recovered = [t for t, v in lag_series if t > up.t and v < HIGH_LAG]
        if recovered:
            rows.append(("elasticity_lag_recovery", (recovered[0] - up.t) * 1e6,
                         f"high_water={HIGH_LAG:.0f}"))
    if downs:
        rows.append(("elasticity_scale_down", (downs[0].t - t0) * 1e6,
                     f"devices={downs[0].devices_before}->{downs[0].devices_after}"))
    peak = max((v for _, v in lag_series), default=0.0)
    devs = [v for _, v in bus.series("elastic.devices")]
    rows.append(("elasticity_peak_lag", 0.0,
                 f"records={peak:.0f};devices_max={max(devs, default=0):.0f};"
                 f"devices_final={devs[-1] if devs else 0:.0f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")

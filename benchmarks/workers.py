"""Multiprocess partition-executor scaling (docs/workers.md).

Pre-produces a keyed message set, then drains it through a
ContinuousStream whose window_fn burns ~2 ms of CPU per firing — the
regime the mp executor exists for: with ``executor="inline"`` every
firing serializes behind the GIL on the record loop; with
``executor="mp"`` each partition owner fires in its own process. Reports
end-to-end msgs/s for the inline baseline and for 1/2/4 worker
processes, plus the supervisor's crash-recovery latency (SIGKILL a
worker mid-stream, time until the respawned process has replayed
checkpoint + journal and the stream fires again).

The per-firing burn has two modes. ``cpu`` is pure numpy arithmetic —
the honest test, but it can only scale when the host actually has cores
to give the workers. ``block`` sleeps instead (an external call / a
device dispatch): it still proves firings execute *concurrently* across
worker processes, which is the property the runtime owns, and it works
on single-core CI containers. The default picks ``cpu`` when >= 4 CPUs
are available and ``block`` otherwise; the chosen mode and the CPU count
are recorded in the JSON so the artifact can't mislead.

Writes ``BENCH_workers.json`` next to this file; ``--quick`` trims the
message count for CI bench-smoke. Acceptance bar: >1.8x throughput going
1 -> 4 workers (``scaling_ok`` in the JSON).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import statistics
import time

import numpy as np

from repro.broker import Producer
from repro.core import PilotComputeService
from repro.streaming import TumblingWindow

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_workers.json")

N_KEYS = 16
WINDOW = 0.2
DT = 0.005
BASE_TS = 1000.0
N_MSGS = 4000
QUICK_MSGS = 1600

#: a few ms per window firing either way (cpu mode calibrated loosely; the
#: benchmark compares executors against each other, not against a clock)
_BURN_ITERS = 40
_BURN_SIZE = 16384
_BLOCK_S = 0.003

_BURN_MODE = "cpu"  # module-global so fork()ed workers inherit it


def _cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # non-Linux
        return os.cpu_count() or 1


def _window_fn(key, w, msgs):
    if _BURN_MODE == "cpu":
        x = np.full(_BURN_SIZE, 1.000001)
        for _ in range(_BURN_ITERS):
            x = np.sqrt(x * x + 1e-9)
        bias = float(x[0]) - 1.0
    else:
        time.sleep(_BLOCK_S)
        bias = 0.0
    total = float(np.sum([m.value[1] for m in msgs])) + bias
    return key, w, total, len(msgs)


def _expected_windows(n_msgs: int) -> int:
    return (int(n_msgs * DT / WINDOW) - 1) * N_KEYS


def _run(n_msgs: int, *, executor: str, n_workers: int, kill: bool = False) -> dict:
    svc = PilotComputeService(devices=list(range(8)))
    try:
        kafka = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"})
        cluster = kafka.get_context()
        cluster.create_topic("bench", 1)
        flink = svc.submit_pilot({"number_of_nodes": 1,
                                  "cores_per_node": n_workers, "type": "flink"})
        fired = []
        stream = flink.get_context().stream(
            cluster, "bench", group="g",
            assigner=TumblingWindow(WINDOW),
            window_fn=_window_fn,
            key_fn=lambda m: int(m.value[0]),
            emit=fired.append,
            executor=executor,
            worker_options={"snapshot_every": 16} if executor == "mp" else None,
        )
        # pre-produce everything so the drain is compute-bound, not
        # producer-bound
        prod = Producer(cluster, "bench", serializer="npy")
        for i in range(n_msgs):
            prod.send(np.array([i % N_KEYS, float(i)], dtype=np.float64),
                      timestamp=BASE_TS + i * DT)
        expected = _expected_windows(n_msgs)
        t0 = time.perf_counter()
        stream.start()
        restart_latency_ms = None
        if kill:
            stream.await_windows(expected // 3, timeout=120)
            victim = stream.runtime._sups[0]
            n_before = len(fired)
            tk = time.perf_counter()
            os.kill(victim.process.pid, signal.SIGKILL)
            # recovered = respawned worker replayed its spool and the
            # stream fired again
            while len(fired) <= n_before:
                time.sleep(0.001)
            restart_latency_ms = (time.perf_counter() - tk) * 1e3
        stream.await_windows(expected, timeout=300)
        wall_s = time.perf_counter() - t0
        stream.stop()
        restarts = stream.runtime.restarts if stream.runtime is not None else 0
        return {
            "executor": executor,
            "n_workers": n_workers if executor == "mp" else 0,
            "msgs": n_msgs,
            "fired_windows": stream.stats.fired_windows,
            "wall_s": wall_s,
            "msgs_per_s": n_msgs / wall_s,
            "restarts": restarts,
            "restart_latency_ms": restart_latency_ms,
        }
    finally:
        svc.cancel()


def run(quick: bool = False, repeats: int = 3, burn: str = "auto") -> dict:
    global _BURN_MODE
    if burn == "auto":
        burn = "cpu" if _cpus() >= 4 else "block"
    _BURN_MODE = burn
    print(f"burn mode: {burn} ({_cpus()} CPUs available)")
    n_msgs = QUICK_MSGS if quick else N_MSGS
    rows = []
    for executor, n_workers in [("inline", 1), ("mp", 1), ("mp", 2), ("mp", 4)]:
        samples = [_run(n_msgs, executor=executor, n_workers=n_workers)
                   for _ in range(repeats)]
        best = max(s["msgs_per_s"] for s in samples)
        row = dict(samples[0])
        row["msgs_per_s"] = best
        row["wall_s"] = min(s["wall_s"] for s in samples)
        rows.append(row)
        label = executor if executor == "inline" else f"mp x{n_workers}"
        print(f"{label:>8}: {best:10.0f} msgs/s  ({row['wall_s']:.2f} s, "
              f"{row['fired_windows']} windows)")

    by = {(r["executor"], r["n_workers"]): r["msgs_per_s"] for r in rows}
    speedup = by[("mp", 4)] / by[("mp", 1)]
    kills = [_run(n_msgs, executor="mp", n_workers=4, kill=True)
             for _ in range(repeats)]
    restart_ms = statistics.median(k["restart_latency_ms"] for k in kills)
    print(f"speedup mp 1->4: {speedup:.2f}x   restart latency: {restart_ms:.0f} ms")
    return {
        "benchmark": "workers",
        "n_keys": N_KEYS,
        "repeats": repeats,
        "burn_mode": burn,
        "cpus": _cpus(),
        "results": rows,
        "speedup_1_to_4": speedup,
        "scaling_ok": speedup > 1.8,
        "restart_latency_ms_median": restart_ms,
        "restart_recovered_all": all(
            k["fired_windows"] == _expected_windows(n_msgs) and k["restarts"] >= 1
            for k in kills),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--burn", choices=["auto", "cpu", "block"], default="auto",
                    help="per-firing cost model (auto: cpu when >=4 CPUs)")
    args = ap.parse_args()
    out = run(quick=args.quick, repeats=args.repeats, burn=args.burn)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out} (scaling_ok={out['scaling_ok']})")


if __name__ == "__main__":
    main()

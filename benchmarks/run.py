"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only startup,latency,...]

Prints ``name,us_per_call,derived`` CSV rows (paper Figs. 6-9 analogs +
kernel micro-benchmarks + the roofline summary from dry-run artifacts).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

SUITES = ("startup", "latency", "producer_throughput", "processing_throughput",
          "elasticity", "predictive", "kernel_bench", "hotpath")


def _roofline_rows() -> list[tuple[str, float, str]]:
    """Summarize the dry-run roofline artifacts if present (see launch/dryrun)."""
    path = os.path.join(os.path.dirname(__file__), "roofline_opt.json")
    if not os.path.exists(path):
        path = os.path.join(os.path.dirname(__file__), "roofline_baseline.json")
    if not os.path.exists(path):
        return [("roofline", 0.0, "missing: run launch.dryrun + launch.roofline first")]
    with open(path) as f:
        rows = json.load(f)
    out = []
    for r in rows:
        ideal = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append((
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            ideal * 1e6,
            f"bottleneck={r['dominant']};fraction={r['fraction']:.3f}",
        ))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for suite in SUITES:
        if only and suite not in only:
            continue
        try:
            mod = __import__(f"benchmarks.{suite}", fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception:
            failures += 1
            traceback.print_exc()
    if only is None or "roofline" in (only or set()):
        for name, us, derived in _roofline_rows():
            print(f"{name},{us:.1f},{derived}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Predictive vs reactive scaling on a seeded rate-step trace.

A deterministic discrete-time queue simulator drives the *real* scaling
policies (:class:`ForecastPolicy` vs :class:`ThresholdHysteresisPolicy`
vs :class:`PIDScalingPolicy`) head-to-head on the same arrival trace: a
Poisson-ish rate-step workload served at ``MU`` records/s/device, where
every rescale pays a migration pause (service halts, arrivals pile up)
that is fed back to the policies as ``MetricsSnapshot.state_migration_ms``
— exactly the signal the forecast policy's migration gate consumes.

The controller mechanics mirror ``ElasticController``: cooldown gated
before the policy is consulted, relative deltas in lease units, absolute
targets rounded up on grow / down on shrink, clamped to
``[MIN_DEVICES, MAX_DEVICES]``.

Two costs are integrated over the run and both must favor the forecast
policy for the acceptance bar of the predictive-scheduling PR:

* ``lag_seconds``    — backlog integral (record-seconds of queueing): the
  SLO side. Reactive policies only move after lag has accrued; the
  forecast policy sizes from the arrival estimate.
* ``device_seconds`` — devices held integral: the cost side. Hysteresis
  holds surplus devices through its down-stability window; an absolute
  forecast target releases them the tick the predicted load drops.

Emits ``BENCH_predictive.json`` (CI bench-smoke artifact) and returns
summary rows for ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import random

from repro.elastic import (
    ForecastPolicy,
    MetricsSnapshot,
    PIDScalingPolicy,
    ThresholdHysteresisPolicy,
)

OUT_DEFAULT = os.path.join(os.path.dirname(__file__), "BENCH_predictive.json")

DT = 0.25               # simulator tick (s) == controller interval
MU = 50.0               # true service rate (records/s/device)
MIGRATION_S = 0.4       # rescale pause: quiesce + snapshot + restore
MIN_DEVICES, MAX_DEVICES = 1, 8
COOLDOWN = 1.0          # ElasticConfig.cooldown
MIGRATION_COST_FRAC = 0.1  # ElasticConfig.migration_cost_frac (amortization)
SEED = 7

#: (duration_s, arrival records/s) — calm, surge, partial relief, calm
TRACE = ((20.0, 40.0), (30.0, 220.0), (25.0, 120.0), (25.0, 40.0))


def _policies():
    return {
        "threshold": ThresholdHysteresisPolicy(
            high_lag=80.0, low_lag=15.0, up_stable=2, down_stable=3),
        "pid": PIDScalingPolicy(
            target_lag=40.0, kp=1.0, ki=0.1, kd=0.0, lag_per_device=100.0),
        # gain ratio > 1: a one-device nudge while the backlog drains is
        # not worth a migration pause; the big corrections still clear it
        "forecast": ForecastPolicy(
            target_lag=20.0, horizon=3.0, headroom=0.1,
            min_observations=3, migration_gain_ratio=2.0),
    }


def _rate_at(trace, t):
    for dur, rate in trace:
        if t < dur:
            return rate
        t -= dur
    return trace[-1][1]


def simulate(policy, trace, *, seed=SEED):
    """Run one policy over the trace; identical seeded arrival noise per
    policy, so the comparison is purely the scaling behavior."""
    rng = random.Random(seed)
    total = sum(d for d, _ in trace)
    n_ticks = int(round(total / DT))
    devices, lag = MIN_DEVICES, 0.0
    pause_left = 0.0
    migration_ms, migration_t = 0.0, 0.0
    last_action_t = -COOLDOWN
    lag_seconds = device_seconds = peak_lag = 0.0
    rescales = 0
    timeline = []
    # throughput gauge averaged since the last policy-visible snapshot —
    # like a real bus gauge, and consistent with d(lag)/dt over the same
    # window (instantaneous per-tick rates would break flow conservation
    # across a migration pause)
    served_acc, cap_acc, snap_t = 0.0, 0.0, -DT

    for i in range(n_ticks):
        t = i * DT
        arrivals = _rate_at(trace, t) * max(rng.gauss(1.0, 0.03), 0.0) * DT
        capacity = 0.0 if pause_left > 0 else MU * devices * DT
        pause_left = max(pause_left - DT, 0.0)
        served = min(lag + arrivals, capacity)
        lag = lag + arrivals - served
        lag_seconds += lag * DT
        device_seconds += devices * DT
        peak_lag = max(peak_lag, lag)
        timeline.append([round(t, 2), round(lag, 1), devices])
        served_acc += served
        cap_acc += capacity

        # ElasticController.step: cooldown and the migration-amortization
        # deferral both gate BEFORE the policy runs, so gated ticks produce
        # no snapshot for the policy to observe
        if t - last_action_t < COOLDOWN:
            continue
        if migration_ms > 0 and \
                t - migration_t < (migration_ms / 1e3) / MIGRATION_COST_FRAC:
            continue
        window = t - snap_t
        snap = MetricsSnapshot(
            t=t, lag=lag, records_per_sec=served_acc / window,
            processing_delay=0.0, scheduling_delay=0.0,
            busy_frac=served_acc / cap_acc if cap_acc > 0 else 1.0,
            devices_total=MAX_DEVICES, devices_leased=devices,
            utilization=devices / MAX_DEVICES, pipeline_devices=devices,
            state_migration_ms=migration_ms, state_migration_t=migration_t,
        )
        served_acc, cap_acc, snap_t = 0.0, 0.0, t
        decision = policy.decide(snap)
        delta = decision.delta_devices
        if delta == 0:
            continue
        if decision.absolute:
            n = abs(delta)
            want = math.ceil(n) if delta > 0 else n  # lease step == 1 device
        else:
            want = abs(delta)
        target = devices + want if delta > 0 else devices - want
        target = max(MIN_DEVICES, min(MAX_DEVICES, target))
        if target == devices:
            continue
        devices = target
        last_action_t = t
        pause_left = MIGRATION_S  # the rescale pause starts next tick
        migration_ms, migration_t = MIGRATION_S * 1e3, t
        rescales += 1

    return {
        "lag_seconds": round(lag_seconds, 1),
        "device_seconds": round(device_seconds, 1),
        "peak_lag": round(peak_lag, 1),
        "rescales": rescales,
        "final_devices": devices,
        "timeline": timeline,
    }


def run(quick: bool = False, out: str = OUT_DEFAULT):
    scale = 0.5 if quick else 1.0
    trace = tuple((d * scale, r) for d, r in TRACE)
    results = {name: simulate(p, trace) for name, p in _policies().items()}

    fc = results["forecast"]
    reactive_best = {
        "lag_seconds": min(results[n]["lag_seconds"]
                           for n in ("threshold", "pid")),
        "device_seconds": min(results[n]["device_seconds"]
                              for n in ("threshold", "pid")),
    }
    result = {
        "trace": [list(s) for s in trace],
        "mu_records_per_sec_per_device": MU,
        "migration_pause_s": MIGRATION_S,
        "seed": SEED,
        "policies": results,
        "forecast_wins_both": (
            fc["lag_seconds"] < reactive_best["lag_seconds"]
            and fc["device_seconds"] < reactive_best["device_seconds"]),
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    rows = []
    for name, r in results.items():
        rows.append((f"predictive_{name}", 0.0,
                     f"lag_s={r['lag_seconds']};dev_s={r['device_seconds']};"
                     f"peak_lag={r['peak_lag']};rescales={r['rescales']}"))
    rows.append(("predictive_forecast_wins_both", 0.0,
                 f"wins={result['forecast_wins_both']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="half-length trace")
    ap.add_argument("--out", default=OUT_DEFAULT)
    args = ap.parse_args()
    rows = run(quick=args.quick, out=args.out)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    with open(args.out) as f:
        if not json.load(f)["forecast_wins_both"]:
            raise SystemExit("forecast policy did not win on both cost axes")


if __name__ == "__main__":
    main()

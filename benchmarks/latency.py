"""Fig. 7 analog: end-to-end produce->process latency.

Direct broker consumer (the paper's "Kafka client") vs the micro-batch
engine at several batch windows (scaled-down analogs of the paper's
0.2s-8s sweep). Expected shape: latency ~ transport + ~window/2; shrinking
the window drives the micro-batch overhead toward the direct-consumer
floor.
"""
from __future__ import annotations

import statistics
import time

import numpy as np

from repro.broker import Consumer, ConsumerGroup, Producer
from repro.core import PilotComputeService


def _direct_latency(cluster, n: int = 50) -> float:
    cluster.create_topic("lat_direct", 1)
    prod = Producer(cluster, "lat_direct", serializer="npy")
    group = ConsumerGroup(cluster, "g", "lat_direct")
    cons = Consumer(cluster, group, "m")
    lats = []
    for i in range(n):
        prod.send(np.array([time.time()]))
        msgs = cons.poll(1, timeout=2.0)
        lats.append(time.time() - msgs[0].timestamp)
    return statistics.median(lats)


def _microbatch_latency(cluster, ctx, window: float, n: int = 30) -> float:
    topic = f"lat_mb_{int(window * 1000)}"
    cluster.create_topic(topic, 1)
    prod = Producer(cluster, topic, serializer="npy", rate_msgs_per_s=max(20, 4 / window))
    lats = []

    def process(state, msgs):
        now = time.time()
        lats.extend(now - m.timestamp for m in msgs)
        return state

    stream = ctx.stream(cluster, topic, group=f"g{topic}", process_fn=process,
                        batch_interval=window, backpressure=False)
    stream.start()
    for i in range(n):
        prod.send(np.array([time.time()]))
    deadline = time.monotonic() + 20
    while len(lats) < n and time.monotonic() < deadline:
        time.sleep(0.01)
    stream.stop()
    return statistics.median(lats) if lats else float("nan")


def run() -> list[tuple[str, float, str]]:
    svc = PilotComputeService()
    cluster = svc.submit_pilot({"number_of_nodes": 1, "type": "kafka"}).get_context()
    ctx = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"}).get_context()
    rows = []
    direct = _direct_latency(cluster)
    rows.append(("latency_direct_consumer", direct * 1e6, f"median_s={direct:.4f}"))
    for window in (0.05, 0.2, 0.8):
        lat = _microbatch_latency(cluster, ctx, window)
        rows.append(
            (f"latency_microbatch_{int(window*1000)}ms", lat * 1e6,
             f"median_s={lat:.4f};window_s={window}")
        )
    svc.cancel()
    return rows

"""Data-plane throughput: log vs shared-memory transport (docs/transport.md).

A :class:`repro.miniapps.DetectorSimSource` streams fixed-size detector
frames (128x128 uint16, the instrument-ingest shape the transport exists
for) through one topic while 1/2/4 independent consumer groups drain it
concurrently — the multi-pipeline fan-out of a beamline deployment. Both
runs use the same batch API (``Producer.send_batch``); the only variable
is the data plane:

* ``log``  — payloads ride the partition log: one npy serialize + append
  per message on the way in, one npy decode + copy per message out.
* ``shm``  — payloads ride a mounted ring: one columnar frame write per
  batch, per-message records carry ~40-byte slot handles, and consumers
  decode ``numpy.frombuffer`` views (zero per-message serde or copies).

Reports msgs/s and MB/s per (transport, consumer-count) cell plus the
shm/log speedup per cell, and asserts nothing was lost: every consumer
group receives every message (``lost_records == 0``).

Writes ``BENCH_transport.json`` next to this file; ``--quick`` trims the
message count for CI bench-smoke. Acceptance bar: >= 5x msgs/s on shm at
equal payload size (``speedup_ok`` in the JSON).
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

from repro.broker.cluster import BrokerCluster
from repro.broker.consumer import Consumer, ConsumerGroup
from repro.miniapps import DetectorSimSource, SourceConfig
from repro.transport import ShmTransport

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_transport.json")

NY, NX, DTYPE = 128, 128, "uint16"
FRAME_BYTES = NY * NX * 2
# pulse-train batching: detectors ship a train of frames per message
# burst (32 here), which is also what amortizes the per-batch frame
# encode + slot write on the shm path
FRAMES_PER_BATCH = 32
N_MSGS = 8000
QUICK_MSGS = 2048


def _drain(consumer: Consumer, want: int, counts: list, idx: int) -> None:
    got = 0
    while got < want:
        msgs = consumer.poll(max_records=512, timeout=0.5)
        if msgs:
            got += len(msgs)
            consumer.commit()  # progress drives shm slot reclaim
    counts[idx] = got


def _run(n_msgs: int, *, transport: str, n_consumers: int) -> dict:
    cluster = BrokerCluster(1)
    try:
        if transport == "shm":
            # a slot holds one train (32 x 32KB + header)
            shm = ShmTransport(slot_bytes=1 << 21, n_slots=64)
            cluster.attach_transport(shm)
        cluster.create_topic("frames", 1)
        if transport == "shm":
            cluster.transport.mount("frames")
        # groups register before the stream starts: a registered group with
        # no progress holds every slot, so no consumer can miss a frame
        consumers = [
            Consumer(cluster, ConsumerGroup(cluster, f"g{i}", "frames"),
                     f"m{i}", zero_copy=(transport == "shm"))
            for i in range(n_consumers)
        ]
        counts = [0] * n_consumers
        threads = [
            threading.Thread(target=_drain, args=(c, n_msgs, counts, i),
                             daemon=True)
            for i, c in enumerate(consumers)
        ]
        source = DetectorSimSource(
            cluster, SourceConfig("frames", total_messages=n_msgs),
            ny=NY, nx=NX, dtype=DTYPE, frames_per_batch=FRAMES_PER_BATCH)
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        source.start()
        for t in threads:
            t.join(timeout=600)
        wall_s = time.perf_counter() - t0
        source.stop()
        for c in consumers:
            c.close()
        lost = (cluster.lost_records
                + sum(n_msgs - got for got in counts))
        return {
            "transport": transport,
            "n_consumers": n_consumers,
            "msgs": n_msgs,
            "wall_s": wall_s,
            "msgs_per_s": n_msgs / wall_s,
            "mb_per_s": n_msgs * FRAME_BYTES * n_consumers / wall_s / 1e6,
            "lost_records": lost,
        }
    finally:
        cluster.close()


def run(quick: bool = False, repeats: int = 3) -> dict:
    n_msgs = QUICK_MSGS if quick else N_MSGS
    rows = []
    for transport in ("log", "shm"):
        for n_consumers in (1, 2, 4):
            samples = [_run(n_msgs, transport=transport,
                            n_consumers=n_consumers) for _ in range(repeats)]
            row = dict(max(samples, key=lambda s: s["msgs_per_s"]))
            row["lost_records"] = sum(s["lost_records"] for s in samples)
            rows.append(row)
            print(f"{transport:>4} x{n_consumers} consumers: "
                  f"{row['msgs_per_s']:10.0f} msgs/s  "
                  f"{row['mb_per_s']:8.1f} MB/s  ({row['wall_s']:.2f} s)")
    by = {(r["transport"], r["n_consumers"]): r["msgs_per_s"] for r in rows}
    speedups = {str(n): by[("shm", n)] / by[("log", n)] for n in (1, 2, 4)}
    print("shm/log speedup: " + "  ".join(
        f"x{n}={s:.1f}x" for n, s in speedups.items()))
    return {
        "benchmark": "transport",
        "payload": {"ny": NY, "nx": NX, "dtype": DTYPE,
                    "frame_bytes": FRAME_BYTES,
                    "frames_per_batch": FRAMES_PER_BATCH},
        "repeats": repeats,
        "results": rows,
        "speedup_shm_vs_log": speedups,
        "speedup_ok": speedups["1"] >= 5.0,
        "lost_records": sum(r["lost_records"] for r in rows),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    out = run(quick=args.quick, repeats=args.repeats)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out} (speedup_ok={out['speedup_ok']}, "
          f"lost_records={out['lost_records']})")


if __name__ == "__main__":
    main()

"""Fault-recovery latency and failover cost (docs/faults.md).

Drives a live deterministic source through an inline ContinuousStream and
injects one fault per run via :mod:`repro.faults`:

* ``kill_broker_node`` — leader loss on a replicated topic with a
  leader-election blackout: recovery latency is the consumer's stall (from
  injection until records flow again), plus the throughput dip across the
  blackout and the acked-record-loss count (pinned to zero by acks-all
  replication);
* ``kill_pilot`` — stage-pilot crash recovered by the StageReconciler:
  end-to-end outage (heartbeat detection + reprovision + checkpoint
  restore) and the stream's own ``recover()`` latency;
* ``slow_consumer`` — an injected poll delay that expires mid-stream:
  degraded-mode throughput ratio while the fault is active.

Every faulted run's window outputs are compared against the fault-free
baseline (``outputs_match_baseline``) — the recovery numbers only count if
nothing was lost or duplicated. Writes ``BENCH_faults.json`` next to this
file; ``--quick`` trims the message count for CI bench-smoke.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import PilotComputeService
from repro.faults import FaultInjector, FaultSchedule
from repro.miniapps import SourceConfig
from repro.miniapps.mass import StreamSource
from repro.pipeline.runner import StageReconciler
from repro.streaming import TumblingWindow

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_faults.json")

N_MSGS = 3000
QUICK_MSGS = 1500
RATE = 2000.0  # msgs/s — constant, so a throughput dip is attributable
DT = 0.01
WINDOW = 0.1
N_KEYS = 5
BASE_TS = 1000.0
BLACKOUT = 0.25


class _DeterministicSource(StreamSource):
    def make_message(self, rng, i):
        return np.array([i % N_KEYS, float(i) * 1.25], dtype=np.float64)

    def make_timestamp(self, rng, i):
        return BASE_TS + i * DT


def _window_fn(key, w, msgs):
    vals = np.array([m.value[1] for m in msgs], dtype=np.float64)
    return key, w, float(np.sum(vals)), len(msgs)


def _expected_windows(n_msgs: int) -> int:
    return (int(n_msgs * DT / WINDOW) - 1) * N_KEYS


def _run(n_msgs: int, schedule=None, *, broker_nodes=1, replication_factor=1,
         checkpoint_every=0, reconcile=False) -> dict:
    svc = PilotComputeService(devices=list(range(10)),
                              heartbeat_interval=0.05, heartbeat_timeout=0.25)
    results: dict = {}
    injector = reconciler = None
    flink_pcd = {"number_of_nodes": 1, "cores_per_node": 2, "type": "flink"}
    try:
        kafka = svc.submit_pilot({"number_of_nodes": broker_nodes, "type": "kafka"})
        cluster = kafka.get_context()
        cluster.create_topic("bench", 1, replication_factor=replication_factor)
        flink = svc.submit_pilot(flink_pcd)
        stream = flink.get_context().stream(
            cluster, "bench", group="g",
            assigner=TumblingWindow(WINDOW),
            window_fn=_window_fn,
            key_fn=lambda m: int(m.value[0]),
            emit=lambda out: results.__setitem__((out[0], out[1]), (out[2], out[3])),
            checkpoint_every=checkpoint_every,
        )
        stream.start()
        if reconcile:
            reconciler = StageReconciler(svc)
            reconciler.manage("bench", flink, stream, flink_pcd)
        source = _DeterministicSource(cluster, SourceConfig(
            "bench", total_messages=n_msgs, n_producers=1, keyed=True,
            seed=7, rate_msgs_per_s=RATE))
        source.start()
        if schedule is not None:
            injector = FaultInjector(schedule, seed=0, cluster=cluster,
                                     topic="bench", stream=stream,
                                     service=svc, pilot=flink).start()
        expected = _expected_windows(n_msgs)
        timeline: list[tuple[float, int]] = []  # (t, records consumed)
        t_fault = rec_at_fault = None
        recovery_s = None
        t0 = time.perf_counter()
        deadline = t0 + 120
        while stream.stats.fired_windows < expected:
            now = time.perf_counter()
            assert now < deadline, (
                f"stalled at {stream.stats.fired_windows}/{expected}; "
                f"events={injector.events if injector else []}")
            rec = stream.stats.records
            timeline.append((now, rec))
            if injector is not None and t_fault is None and injector.events:
                t_fault, rec_at_fault = now, rec
            elif t_fault is not None and recovery_s is None and rec != rec_at_fault:
                # progress after the fault: crash recovery restores a lower
                # checkpointed count, a blackout resumes a higher one
                recovery_s = now - t_fault
            time.sleep(0.002)
        wall_s = time.perf_counter() - t0
        source.stop()
        if injector is not None:
            injector.stop()
        if reconciler is not None:
            reconciler.close()
        stream.stop()
        return {
            "results": results,
            "wall_s": wall_s,
            "fired": stream.stats.fired_windows,
            "late": stream.stats.late_records,
            "recovery_s": recovery_s,
            "t_fault_rel": None if t_fault is None else t_fault - t0,
            "timeline": [(t - t0, r) for t, r in timeline],
            "failovers": cluster.failovers,
            "lost": cluster.lost_records,
            "prod_retries": sum(p.retries for p in source.producers),
            "cons_retries": stream.consumer.retries,
            "stream_recovery_ms": stream.last_recovery_ms,
            "stage_recoveries": reconciler.recoveries if reconciler else 0,
        }
    finally:
        svc.cancel()


def _rate(timeline, t_lo, t_hi) -> float:
    """Consumed records/s over [t_lo, t_hi) of a (t, records) timeline."""
    pts = [(t, r) for t, r in timeline if t_lo <= t < t_hi]
    if len(pts) < 2 or pts[-1][0] == pts[0][0]:
        return 0.0
    return (pts[-1][1] - pts[0][1]) / (pts[-1][0] - pts[0][0])


def run(quick: bool = False) -> dict:
    n_msgs = QUICK_MSGS if quick else N_MSGS
    at = n_msgs // 2
    expected = _expected_windows(n_msgs)

    base = _run(n_msgs)
    assert base["fired"] == expected and base["late"] == 0
    print(f"baseline: {base['wall_s']:.2f} s, {base['fired']} windows")

    rows = []

    b = _run(n_msgs,
             FaultSchedule().kill_broker_node(at_records=at, node="leader",
                                              blackout=BLACKOUT),
             broker_nodes=3, replication_factor=2)
    tf = b["t_fault_rel"]
    dip = (_rate(b["timeline"], tf, tf + BLACKOUT + 0.1)
           / max(_rate(b["timeline"], tf - 0.5, tf), 1e-9))
    rows.append({
        "fault": "kill_broker_node",
        "recovery_latency_ms": b["recovery_s"] * 1e3,
        "failovers": b["failovers"],
        "acked_lost_records": b["lost"],
        "retries": b["prod_retries"] + b["cons_retries"],
        "throughput_dip_ratio": dip,  # consumed rate across blackout / before
        "outputs_match_baseline": b["results"] == base["results"],
    })

    p = _run(n_msgs, FaultSchedule().kill_pilot(at_records=at),
             checkpoint_every=100, reconcile=True)
    rows.append({
        "fault": "kill_pilot",
        "recovery_latency_ms": p["recovery_s"] * 1e3,  # detection + reprovision + restore
        "stream_recover_ms": p["stream_recovery_ms"],  # restore alone
        "stage_recoveries": p["stage_recoveries"],
        "acked_lost_records": p["lost"],
        "outputs_match_baseline": p["results"] == base["results"],
    })

    delay = 0.02
    s = _run(n_msgs, FaultSchedule().slow_consumer(
        at_records=at, delay=delay, until_records=at + n_msgs // 5))
    tf = s["t_fault_rel"]
    degraded = (_rate(s["timeline"], tf, tf + 0.4)
                / max(_rate(s["timeline"], tf - 0.5, tf), 1e-9))
    rows.append({
        "fault": "slow_consumer",
        "recovery_latency_ms": s["recovery_s"] * 1e3,
        "degraded_throughput_ratio": degraded,
        "acked_lost_records": s["lost"],
        "outputs_match_baseline": s["results"] == base["results"],
    })

    for r in rows:
        print(f"{r['fault']:>18}: recovery {r['recovery_latency_ms']:7.1f} ms, "
              f"lost={r['acked_lost_records']}, "
              f"identical={r['outputs_match_baseline']}")
    return {
        "benchmark": "faults",
        "msgs": n_msgs,
        "rate_msgs_per_s": RATE,
        "blackout_s": BLACKOUT,
        "baseline_wall_s": base["wall_s"],
        "results": rows,
        "acked_loss_total": sum(r["acked_lost_records"] for r in rows),
        "all_outputs_identical": all(r["outputs_match_baseline"] for r in rows),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    out = run(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out} (loss={out['acked_loss_total']}, "
          f"identical={out['all_outputs_identical']})")


if __name__ == "__main__":
    main()

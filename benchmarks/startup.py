"""Fig. 6 analog: framework startup time vs cluster size.

On TPU the "cluster start" is lease acquisition + plugin provisioning +
(for compute engines) step lowering; a configurable per-node provision delay
emulates the batch-scheduler/bootstrap latency of real HPC clusters (the
paper's dominant term). Expected shape: startup grows with node count;
broker ("kafka") > engines; all ≪ streaming-app lifetime.
"""
from __future__ import annotations

import time

from repro.core import PilotComputeService


def run(provision_delay_per_node: float = 0.02) -> list[tuple[str, float, str]]:
    rows = []
    for framework in ("kafka", "spark", "dask"):
        for nodes in (1, 2, 4, 8):
            svc = PilotComputeService(provision_delay_per_node=provision_delay_per_node)
            t0 = time.monotonic()
            pilot = svc.submit_pilot({"number_of_nodes": nodes, "type": framework})
            dt = time.monotonic() - t0
            if framework == "kafka":  # include topic provisioning like the paper
                pilot.get_context().create_topic("t", nodes * 4)
            rows.append(
                (f"startup_{framework}_{nodes}nodes", dt * 1e6, f"startup_s={dt:.4f}")
            )
            svc.cancel()
    return rows

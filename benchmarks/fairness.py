"""Multi-tenant fairness under the resource arbiter (docs/scheduler.md).

Two pipelines with 2:1 fair-share weights contend for a pool too small for
both; the arbiter's weighted fair share should converge their device split
to ~2:1. Then a high-priority tenant arrives and the arbiter preempts the
pipelines down to their floors — the benchmark records the per-tick split
and the wall-clock preemption latency (demand filed -> devices revoked).

Emits ``BENCH_fairness.json`` (CI artifact, next to this file by default)
and returns summary rows for ``benchmarks/run.py``:

* fairness_ratio        — final A:B device ratio (target 2.0)
* fairness_convergence  — reconcile ticks until the split stabilizes
* fairness_preemption   — latency from high-priority demand to revocation
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import PilotComputeService
from repro.elastic import MetricsBus
from repro.pipeline import Pipeline, register_processor
from repro.scheduler import PoolTenant

POOL_DEVICES = 9  # 2 floors + 6 contended plus one spare: exact 2:1 split
OUT_DEFAULT = os.path.join(os.path.dirname(__file__), "BENCH_fairness.json")


@register_processor("fairness_noop")
def _noop(state, msgs):
    return (state or 0) + len(msgs)


def _pipeline(name: str, share: float):
    return (Pipeline.named(name).share(share)
            .topic("in", partitions=2)
            .source("in", kind="cluster", rate_msgs_per_s=40)
            .stage("work", topic="in", processor="fairness_noop",
                   batch_interval=0.05, backpressure=False)
            # greedy demand: always asks for more, so contention is constant
            # and the split is decided purely by the arbiter's weights
            .elastic("work", policy="threshold", high_lag=-1.0, low_lag=-2.0,
                     up_stable=1, interval=999.0, cooldown=0.0,
                     min_devices=1, max_devices=POOL_DEVICES)
            .build())


def run(ticks: int = 12, settle: int = 3):
    bus = MetricsBus()
    svc = PilotComputeService(devices=list(range(POOL_DEVICES)), metrics=bus)
    run_a = _pipeline("A", 2.0).run(service=svc, bus=bus).start()
    run_b = _pipeline("B", 1.0).run(service=svc, bus=bus).start()
    arb = svc.arbiter
    ca, cb = run_a.controller("work"), run_b.controller("work")

    split_timeline = []
    converged_at = None
    try:
        # phase 1 — deterministic: pause the background loop (the runs'
        # retain() started it) so the only reconciles are the manual ones,
        # and each tick records exactly one row of the split
        arb.stop()
        for tick in range(ticks):
            ca.step()
            cb.step()
            arb.reconcile()
            split_timeline.append([tick, ca.devices, cb.devices])
            if converged_at is None and len(split_timeline) >= settle and all(
                row[1:] == split_timeline[-1][1:]
                for row in split_timeline[-settle:]
            ):
                converged_at = tick
        a_dev, b_dev = ca.devices, cb.devices

        # phase 2 — a high-priority tenant arrives; the background reconcile
        # loop (restarted, then woken by the demand filing) must preempt
        # within ~1 interval
        arb.start()
        tenant = PoolTenant(svc)
        req = tenant.request("hi-pri", min_devices=0,
                             max_devices=POOL_DEVICES, priority=1)
        t_submit = time.monotonic()
        arb.submit(req)
        arb.update("hi-pri", 6)
        deadline = t_submit + 10.0
        while time.monotonic() < deadline and tenant.devices < 6:
            time.sleep(0.005)
        preempt_latency = time.monotonic() - t_submit
        preempted = [e for e in arb.events if e.action == "preempt"]
        result = {
            "pool_devices": POOL_DEVICES,
            "shares": {"A": 2.0, "B": 1.0},
            "split_timeline": split_timeline,
            "final_split": {"A": a_dev, "B": b_dev},
            "ratio": a_dev / b_dev if b_dev else float("inf"),
            "converged_at_tick": converged_at,
            "preemption": {
                "latency_s": round(preempt_latency, 4),
                "arbiter_interval_s": arb.interval,
                "preempt_events": len(preempted),
                "tenant_devices": tenant.devices,
                "split_after": {"A": ca.devices, "B": cb.devices},
            },
        }
    finally:
        run_a.stop()
        run_b.stop()
        svc.cancel()
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer ticks")
    ap.add_argument("--out", default=OUT_DEFAULT)
    args = ap.parse_args()
    # growth reaches the 6:3 fixed point around tick 4; the settle window
    # needs 3 stable rows on top, so even --quick must run >= 8 ticks for
    # converged_at_tick to be non-null in the CI artifact
    result = run(ticks=9 if args.quick else 12)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    rows = [
        ("fairness_ratio", 0.0,
         f"A={result['final_split']['A']};B={result['final_split']['B']};"
         f"ratio={result['ratio']:.2f}"),
        ("fairness_convergence", 0.0,
         f"ticks={result['converged_at_tick']}"),
        ("fairness_preemption", result["preemption"]["latency_s"] * 1e6,
         f"events={result['preemption']['preempt_events']};"
         f"interval_s={result['preemption']['arbiter_interval_s']}"),
    ]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

"""Fig. 8 analog: MASS producer throughput into the broker.

Sweeps source type (kmeans-random / kmeans-static / lightsource) x producer
count x broker nodes, with a per-node I/O budget so the 1-broker bottleneck
of the paper reproduces. Expected shapes: static > random (no RNG cost);
lightsource moves the most MB/s (2 MB messages); 1-broker configs flatten
first; more producers help until the broker budget binds.
"""
from __future__ import annotations

import time

from repro.core import PilotComputeService
from repro.miniapps import SOURCES, SourceConfig

# (source, kwargs, n_msgs, io_rate_per_node): budgets sized so the broker
# bucket BINDS for template sources (several bucket-fills per run) while the
# kmeans-random case stays RNG-bound — the two regimes of paper Fig. 8
CASES = [
    ("cluster", dict(points_per_msg=2000), 48, 64 * 1024 * 1024),
    ("static", dict(points_per_msg=2000), 512, 4 * 1024 * 1024),
    ("lightsource", dict(n_angles=90, n_det=724), 384, 16 * 1024 * 1024),
]


def run() -> list[tuple[str, float, str]]:
    rows = []
    for src_name, kwargs, n_msgs, io_rate in CASES:
        for n_producers in (1, 2, 4):
            for n_brokers in (1, 2):
                svc = PilotComputeService()
                pilot = svc.submit_pilot({
                    "number_of_nodes": n_brokers, "type": "kafka",
                    "io_rate_per_node": io_rate,
                })
                cluster = pilot.get_context()
                cluster.create_topic("t", max(4, n_producers * 2))
                cfg = SourceConfig("t", total_messages=n_msgs, n_producers=n_producers)
                source = SOURCES[src_name](cluster, cfg, **kwargs)
                t0 = time.monotonic()
                source.start()
                source.join(timeout=180)
                dt = time.monotonic() - t0
                mb = source.sent_bytes / 1e6
                rows.append((
                    f"produce_{src_name}_{n_producers}p_{n_brokers}b",
                    dt / max(source.sent_records, 1) * 1e6,
                    f"msgs_per_s={source.sent_records/dt:.1f};MB_per_s={mb/dt:.1f}",
                ))
                svc.cancel()
    return rows

"""Serving benchmark: lockstep vs continuous batching on a heavy-tail trace.

Both modes replay the SAME seeded heavy-tail request trace
(``repro.serving.trace``) against the same model and params, on a **virtual
clock**: the driver advances time by the *measured* device seconds of each
step and stamps arrival/first-token/finish events on that clock — no
sleeping, so a 30-second workload benchmarks in device time only and the
numbers are deterministic up to device timing noise.

* **lockstep** — the ``LMServeApp`` baseline shape: requests form
  fixed-size batches in arrival order; a batch prefills together (rows
  padded to the longest prompt's bucket) and decodes to the LONGEST output
  budget in the group; every response is delivered when the whole batch
  finishes. The p99 prompt/output holds everyone hostage — that is the
  pathology under test.
* **continuous** — ``repro.serving.ContinuousBatcher``: prompts prefill
  into paged KV-cache slots as they arrive and join the live decode batch
  mid-stream; finished sequences exit per step and free their pages.

Reported per mode: tokens/s (requested tokens over the virtual makespan),
TTFT p50/p99, per-token decode latency, responses delivered, lost requests
(must be 0), admission counters and page-pool utilization (continuous).
A chaos section kills the continuous serving pilot mid-trace and verifies
recovery reproduces the fault-free responses bit-identically — no
duplicates, no losses (docs/serving.md).

    PYTHONPATH=src python -m benchmarks.serving [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "BENCH_serving.json")


def _build(quick: bool):
    import jax

    from repro.configs.registry import get_arch
    from repro.models import build_model
    from repro.serving import TraceConfig, heavy_tail_trace

    cfg = get_arch("smollm-135m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    # Overloaded regime with a heavy output tail: arrivals come in faster
    # than either mode can serve, so the makespan is device-bound and the
    # lockstep convoy (every group decodes to its LONGEST output budget)
    # costs real device seconds instead of hiding in arrival gaps.
    tc = TraceConfig(
        n_requests=32 if quick else 64,
        seed=0,
        rate=1024.0,
        prompt_median=12 if quick else 16,
        prompt_sigma=0.8,
        out_median=3,
        out_sigma=1.8,
        max_prompt=32 if quick else 64,
        max_output=24 if quick else 64,
        vocab=cfg.vocab_size,
    )
    return model, params, heavy_tail_trace(tc)


def _quantiles(xs) -> dict:
    if not xs:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    a = np.asarray(xs, np.float64)
    return {
        "p50": float(np.percentile(a, 50)),
        "p99": float(np.percentile(a, 99)),
        "mean": float(a.mean()),
    }


def _report(trace, results: dict, makespan: float) -> dict:
    """Mode-agnostic scorecard from {rid: {tokens, arrival, first_token,
    finish}} responses stamped on the virtual clock."""
    ttft, per_token = [], []
    delivered_tokens = 0
    for r in trace:
        res = results.get(r.rid)
        if res is None:
            continue
        n = len(res["tokens"])
        delivered_tokens += n
        ttft.append(res["first_token"] - r.arrival)
        if n > 1:
            per_token.append((res["finish"] - res["first_token"]) / (n - 1))
    return {
        "responses": len([r for r in trace if r.rid in results]),
        "lost_requests": len([r for r in trace if r.rid not in results]),
        "delivered_tokens": delivered_tokens,
        "makespan_s": makespan,
        "tokens_per_sec": delivered_tokens / makespan if makespan > 0 else 0.0,
        "ttft_s": _quantiles(ttft),
        "per_token_latency_s": _quantiles(per_token),
    }


# ---------------------------------------------------------------------------
# lockstep baseline (virtual clock)
# ---------------------------------------------------------------------------


def run_lockstep(model, params, trace, *, batch: int = 8, warm: bool = True) -> dict:
    """Fixed batches in arrival order; stacked prefill + fused scan decode to
    the group's longest budget; all responses land when the batch does."""
    import jax
    import jax.numpy as jnp

    from repro.streaming.dispatch import ShapeBuckets, compile_count

    buckets = ShapeBuckets(min_size=8, max_size=64)

    @jax.jit
    def prefill(params, toks, last):
        # ragged rows: gather each row's logit at its own last real token
        # (same last_pos path the paged prefill uses)
        logits, cache = model.prefill(params, {"tokens": toks, "last_pos": last})
        return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), cache

    def make_generate(steps):
        def generate(params, cache, tok, pos):
            # grow the cache for the decode span inside the jit (the
            # satellite fix from LMServeApp: no host-side full-cache copy)
            cache = jax.tree.map(
                lambda c: jnp.pad(
                    c, [(0, 0)] * 2 + [(0, steps + 1)] + [(0, 0)] * (c.ndim - 3))
                if c.ndim >= 4 else c, cache)

            def step(carry, _):
                tok, pos, cache = carry
                pos = pos + 1
                logits, cache = model.decode(
                    params, cache, {"tokens": tok, "positions": pos})
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                return (tok, pos, cache), tok

            (_, _, _), toks = jax.lax.scan(step, (tok, pos, cache), None, length=steps)
            return toks

        return jax.jit(generate)

    gens: dict[int, object] = {}

    def serve_group(group):
        """One stacked batch; returns (device seconds, {rid: tokens tuple})."""
        plen = buckets.fit(max(r.prompt_len for r in group))
        gen = max(r.out_tokens for r in group)  # everyone decodes to the max
        toks = np.zeros((len(group), plen), np.int32)
        last = np.array([r.prompt_len - 1 for r in group], np.int32)
        for i, r in enumerate(group):
            toks[i, : r.prompt_len] = r.prompt
        t0 = time.monotonic()
        tok0, cache = prefill(params, jnp.asarray(toks), jnp.asarray(last))
        if gen > 1:
            rest = gens.setdefault(gen - 1, make_generate(gen - 1))
            out = np.asarray(rest(params, cache, tok0, jnp.asarray(last)))
        jax.block_until_ready(tok0)
        dt = time.monotonic() - t0
        tok = np.asarray(tok0)
        seqs = {}
        for i, r in enumerate(group):
            seq = [int(tok[i, 0])]
            if gen > 1:
                seq += [int(t) for t in out[:, i, 0]]
            seqs[r.rid] = tuple(seq[: r.out_tokens])
        return dt, seqs

    def replay():
        results = {}
        now = 0.0
        for i in range(0, len(trace), batch):
            group = trace[i: i + batch]
            start = max(now, max(r.arrival for r in group))
            dt, seqs = serve_group(group)
            finish = start + dt
            for r in group:
                results[r.rid] = {
                    "tokens": seqs[r.rid], "arrival": r.arrival,
                    # lockstep delivers the whole batch at once: first token
                    # and finish coincide at the batch boundary
                    "first_token": finish, "finish": finish,
                }
            now = finish
        return results, now

    if warm:
        replay()  # compile coverage; virtual clock must not bill compiles
    results, makespan = replay()
    rep = _report(trace, results, makespan)
    rep["batch"] = batch
    rep["compiles"] = {"prefill": compile_count(prefill),
                       "decode": sum(compile_count(g) for g in gens.values())}
    return rep, results


# ---------------------------------------------------------------------------
# continuous batching (virtual clock)
# ---------------------------------------------------------------------------


def _drive_continuous(b, trace):
    """Replay arrivals on the virtual clock: every arrival that is due by
    ``now`` is submitted before the next scheduler step (so a burst joins as
    ONE stacked prefill), and the clock advances by each step's measured
    device time."""
    util = []
    now = 0.0
    i = 0
    while i < len(trace) or not b.idle:
        while i < len(trace) and trace[i].arrival <= now:
            b.submit(trace[i], now)
            i += 1
        if b.idle and i < len(trace):
            now = max(now, trace[i].arrival)  # fast-forward to next arrival
            continue
        dt = b.step(now)
        util.append(b.cache.utilization)
        now += dt if dt > 0 else 1e-6
    return now, util


def run_continuous(model, params, trace, *, n_pages: int = 128,
                   page_size: int = 8, use_kernel: bool = False,
                   max_live: int = 32, decode_quantum: int = 1,
                   warm: bool = True) -> dict:
    from repro.serving import ContinuousBatcher

    b = ContinuousBatcher(model, n_pages=n_pages, page_size=page_size,
                          use_kernel=use_kernel, max_live=max_live,
                          decode_quantum=decode_quantum,
                          max_queue=max(64, len(trace)))
    b.params = params
    warmed = 0
    if warm:
        # Bucket-sweep warmup THEN a full replay: which (rows, table-width)
        # buckets the scheduler visits depends on measured step times, so a
        # replay alone can leave shapes uncompiled and leak a ~0.5 s XLA
        # compile into the timed pass.
        warmed = b.warmup(
            max_prompt=max(r.prompt_len for r in trace),
            max_tokens=max(max(b.prompt_buckets.fit(r.prompt_len),
                               r.total_tokens) for r in trace),
            max_live=max_live)
        _drive_continuous(b, trace)
        b.reset()
    compiles_before = b.prefill_compiles + b.decode_compiles
    makespan, util = _drive_continuous(b, trace)
    leaked = b.prefill_compiles + b.decode_compiles - compiles_before
    assert not (warm and leaked), f"{leaked} compiles leaked into the timed pass"
    rep = _report(trace, b.results, makespan)
    rep["admission"] = b.admission.stats.as_dict()
    rep["page_utilization"] = {"mean": float(np.mean(util)) if util else 0.0,
                               "max": float(np.max(util)) if util else 0.0}
    rep["compiles"] = {"prefill": b.prefill_compiles, "decode": b.decode_compiles,
                       "warmup": warmed, "during_timed": leaked}
    rep["pages"] = {"n_pages": n_pages, "page_size": page_size}
    rep["decode_quantum"] = decode_quantum
    return rep, dict(b.results)


def run_chaos(model, params, trace, fault_free: dict, *, n_pages: int = 128,
              page_size: int = 8, decode_quantum: int = 1) -> dict:
    """Kill the serving pilot mid-trace, recover, and diff the response set
    against the fault-free run."""
    from repro.serving import ContinuousBatcher

    b = ContinuousBatcher(model, n_pages=n_pages, page_size=page_size,
                          decode_quantum=decode_quantum,
                          max_queue=max(64, len(trace)))
    b.params = params
    crash_at = len(trace) // 2
    now = 0.0
    for i, r in enumerate(trace):
        now = max(now, r.arrival)
        b.submit(r, now)
        now += b.step(now)
        if i == crash_at:
            b.crash()
            b.recover()
    b.drain(now)
    identical = sum(
        1 for rid in fault_free
        if rid in b.results and b.results[rid]["tokens"] == fault_free[rid]["tokens"])
    return {
        "crash_at_request": crash_at,
        "responses": len(b.results),
        "lost": len(set(fault_free) - set(b.results)),
        "duplicated": 0,  # delivery asserts on duplicate rids; reaching here means none
        "bit_identical_responses": identical,
        "recovered_ok": identical == len(fault_free) == len(b.results),
    }


# ---------------------------------------------------------------------------


def bench_all(quick: bool, out_path: str = DEFAULT_OUT) -> dict:
    import jax

    from repro.serving import trace_summary

    model, params, trace = _build(quick)
    # prompt buckets span [page_size, 4*page_size]: the full trace's
    # 64-token prompts need 16-token pages
    page_size = 8 if quick else 16
    lockstep, _ = run_lockstep(model, params, trace, batch=8)
    continuous, cont_results = run_continuous(
        model, params, trace, page_size=page_size,
        max_live=16 if quick else 32)
    chaos = run_chaos(model, params, trace, cont_results, page_size=page_size)

    speedup_tps = continuous["tokens_per_sec"] / max(lockstep["tokens_per_sec"], 1e-9)
    speedup_p99 = lockstep["ttft_s"]["p99"] / max(continuous["ttft_s"]["p99"], 1e-9)
    report = {
        "meta": {
            "quick": quick,
            "backend": jax.default_backend(),
            "unix_time": time.time(),
        },
        "trace": trace_summary(trace),
        "lockstep": lockstep,
        "continuous": continuous,
        "chaos": chaos,
        "speedup": {
            "tokens_per_sec": speedup_tps,
            "ttft_p99": speedup_p99,
        },
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def _rows(report: dict) -> list[tuple[str, float, str]]:
    rows = []
    for mode in ("lockstep", "continuous"):
        r = report[mode]
        rows.append((
            f"serving_{mode}",
            r["ttft_s"]["p99"] * 1e6,
            f"tokens_per_s={r['tokens_per_sec']:.1f}"
            f";ttft_p50_s={r['ttft_s']['p50']:.4f}"
            f";lost={r['lost_requests']}",
        ))
    s = report["speedup"]
    rows.append((
        "serving_speedup",
        0.0,
        f"tokens_per_sec={s['tokens_per_sec']:.2f}x"
        f";ttft_p99={s['ttft_p99']:.2f}x"
        f";chaos_ok={report['chaos']['recovered_ok']}",
    ))
    return rows


def run() -> list[tuple[str, float, str]]:
    """benchmarks.run entry point: quick mode, JSON emitted as side effect."""
    return _rows(bench_all(quick=True))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small trace (CI smoke)")
    ap.add_argument("--out", default=DEFAULT_OUT, help="JSON report path")
    args = ap.parse_args()
    report = bench_all(args.quick, args.out)
    for name, us, derived in _rows(report):
        print(f"{name},{us:.1f},{derived}")
    lk, ct, sp = report["lockstep"], report["continuous"], report["speedup"]
    print(f"  tokens/s: {lk['tokens_per_sec']:.1f} -> {ct['tokens_per_sec']:.1f} "
          f"({sp['tokens_per_sec']:.2f}x)")
    print(f"  ttft p99: {lk['ttft_s']['p99']*1e3:.2f}ms -> {ct['ttft_s']['p99']*1e3:.2f}ms "
          f"({sp['ttft_p99']:.2f}x)")
    print(f"  chaos: {report['chaos']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

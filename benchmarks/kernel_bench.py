"""Micro-benchmarks of the MASA compute hot-spots via their jnp reference
paths (XLA-compiled; the Pallas kernels target TPU and only run interpreted
on CPU, so wall-clock here measures the oracle path)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.kmeans import assign_ref
from repro.kernels.tomo import gridrec, mlem, project_ref, shepp_logan


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters


def run() -> list[tuple[str, float, str]]:
    rows = []
    pts = jax.random.normal(jax.random.key(0), (100_000, 3))
    cen = jax.random.normal(jax.random.key(1), (10, 3))
    f = jax.jit(assign_ref)
    dt = _time(f, pts, cen)
    rows.append(("kernel_kmeans_assign_100k", dt * 1e6, f"points_per_s={1e5/dt:.3e}"))

    n, a = 64, 90
    img = shepp_logan(n)
    angles = jnp.linspace(0, jnp.pi, a, endpoint=False)
    sino = project_ref(img, angles, n + 32)
    g = jax.jit(lambda s: gridrec(s, angles, n))
    dt = _time(g, sino)
    rows.append(("kernel_gridrec_64", dt * 1e6, f"frames_per_s={1/dt:.2f}"))
    m = jax.jit(lambda s: mlem(s, angles, n, iters=4))
    dt = _time(m, sino)
    rows.append(("kernel_mlem_64_it4", dt * 1e6, f"frames_per_s={1/dt:.2f}"))
    return rows

"""Micro-benchmarks of the MASA compute hot-spots via their jnp reference
paths (XLA-compiled; the Pallas kernels target TPU and only run interpreted
on CPU, so wall-clock here measures the oracle path)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.kmeans import assign_ref
from repro.kernels.tomo import gridrec, mlem, project_ref, shepp_logan
from repro.models.attention import decode_attention


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters


def run() -> list[tuple[str, float, str]]:
    rows = []
    pts = jax.random.normal(jax.random.key(0), (100_000, 3))
    cen = jax.random.normal(jax.random.key(1), (10, 3))
    f = jax.jit(assign_ref)
    dt = _time(f, pts, cen)
    rows.append(("kernel_kmeans_assign_100k", dt * 1e6, f"points_per_s={1e5/dt:.3e}"))

    n, a = 64, 90
    img = shepp_logan(n)
    angles = jnp.linspace(0, jnp.pi, a, endpoint=False)
    sino = project_ref(img, angles, n + 32)
    g = jax.jit(lambda s: gridrec(s, angles, n))
    dt = _time(g, sino)
    rows.append(("kernel_gridrec_64", dt * 1e6, f"frames_per_s={1/dt:.2f}"))
    m = jax.jit(lambda s: mlem(s, angles, n, iters=4))
    dt = _time(m, sino)
    rows.append(("kernel_mlem_64_it4", dt * 1e6, f"frames_per_s={1/dt:.2f}"))

    # serving decode: one-token GQA attention at a continuous-batching shape
    # (16 live sequences, ragged positions against a 256-token KV window)
    B, S, H, KV, hd = 16, 256, 9, 3, 64
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.arange(B, dtype=jnp.int32) * (S // B) + S // B - 1
    d = jax.jit(lambda q, k, v, p: decode_attention(q, k, v, positions=p))
    dt = _time(d, q, kc, vc, pos)
    rows.append(("kernel_serving_decode_b16_s256", dt * 1e6,
                 f"tokens_per_s={B/dt:.3e}"))
    return rows

"""Migration latency vs. state size (docs/state.md).

Fills a PartitionedStateStore with synthetic keyed window state at several
sizes, then times StateMigrator round trips for the canonical elastic
moves (grow 2->3, shrink 3->2, and a worst-case 1->4 reshard). Each sample
reports wall-clock, bytes spooled, partitions moved and the implied
MB/s — the disruption budget a scaling policy trades against (the
``state.migration_ms`` gauge at runtime).

Writes ``BENCH_rescale_state.json`` next to this file; ``--quick`` trims
the state sizes for CI bench-smoke. The acceptance bar: sub-second
migrations at every benchmarked size (``all_sub_second`` in the JSON).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import numpy as np

from repro.broker.consumer import Message
from repro.state import PartitionedStateStore, StateMigrator

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_rescale_state.json")

#: (label, n_keys, msgs_per_key, payload_floats) — "large" is sized so the
#: worst-case 1->4 full reshard keeps real headroom under the sub-second
#: bar on a loaded machine (a bar with no margin regresses on noise, not
#: on code); ~20k keys was measured at ~1.0s for that move on a busy host
SIZES = [
    ("small", 500, 4, 16),
    ("medium", 5_000, 4, 16),
    ("large", 12_000, 4, 32),
]
QUICK_SIZES = SIZES[:2]

MOVES = [("grow_2_to_3", [0, 1], [0, 1, 2]),
         ("shrink_3_to_2", [0, 1, 2], [0, 1]),
         ("reshard_1_to_4", [0], [0, 1, 2, 3])]


def _fill(n_keys: int, msgs_per_key: int, payload: int) -> PartitionedStateStore:
    store = PartitionedStateStore(128)
    rng = np.random.default_rng(0)
    offset = 0
    for k in range(n_keys):
        for j in range(msgs_per_key):
            store.append(
                f"key-{k}", (float(j), float(j) + 1.0),
                Message(0, offset, j + 0.5, rng.normal(size=payload)),
            )
            offset += 1
    return store


def run(quick: bool = False, repeats: int = 3) -> dict:
    rows = []
    for label, n_keys, msgs_per_key, payload in (QUICK_SIZES if quick else SIZES):
        for move, src, dst in MOVES:
            samples = []
            for _ in range(repeats):
                store = _fill(n_keys, msgs_per_key, payload)
                mig = StateMigrator()
                mig.migrate(store, src)  # place onto the source owner set
                t0 = time.perf_counter()
                report = mig.migrate(store, dst)
                mig.cleanup()  # drop this sample's tempdir spools
                samples.append({
                    "wall_ms": (time.perf_counter() - t0) * 1e3,
                    "migration_ms": report.duration_ms,
                    "bytes_moved": report.bytes_moved,
                    "moved_partitions": len(report.moved),
                    "records_moved": report.buffered_records_moved,
                })
            ms = statistics.median(s["migration_ms"] for s in samples)
            sample = samples[0]
            rows.append({
                "state_size": label,
                "n_keys": n_keys,
                "buffered_records": n_keys * msgs_per_key,
                "payload_floats": payload,
                "move": move,
                "migration_ms_median": ms,
                "bytes_moved": sample["bytes_moved"],
                "moved_partitions": sample["moved_partitions"],
                "moved_fraction": sample["moved_partitions"] / 128,
                "records_moved": sample["records_moved"],
                "mb_per_s": (sample["bytes_moved"] / 1e6) / (ms / 1e3) if ms > 0 else 0.0,
            })
            print(f"{label:>7} {move:<15} {ms:8.1f} ms  "
                  f"{sample['bytes_moved']/1e6:7.2f} MB  "
                  f"{sample['moved_partitions']:3d}/128 partitions")
    return {
        "benchmark": "rescale_state",
        "n_partitions": 128,
        "repeats": repeats,
        "results": rows,
        "all_sub_second": all(r["migration_ms_median"] < 1000.0 for r in rows),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI-sized state only")
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    out = run(quick=args.quick, repeats=args.repeats)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out} (all_sub_second={out['all_sub_second']})")


if __name__ == "__main__":
    main()

"""Fig. 9 analog: MASA processing throughput for the three paper workloads.

KMeans (cheap scoring) vs GridRec (FFT backprojection) vs ML-EM (iterative)
at reduced frame sizes. Expected shape (paper §6.4): KMeans >> GridRec >
ML-EM, ordered by computational complexity.
"""
from __future__ import annotations

import time

from repro.core import PilotComputeService
from repro.miniapps import (
    KMeansClusterSource,
    LightsourceTemplateSource,
    ReconstructionApp,
    SourceConfig,
    StreamingKMeans,
)


def _drain(svc, topic_cfg, source, app, n_msgs, max_batch=4):
    cluster = svc.submit_pilot({"number_of_nodes": 2, "type": "kafka"}).get_context()
    cluster.create_topic("t", 4)
    ctx = svc.submit_pilot({"number_of_nodes": 1, "type": "spark"}).get_context()
    src = source(cluster)
    s = ctx.stream(cluster, "t", group="g", process_fn=app.process,
                   batch_interval=0.02, max_batch_records=max_batch, backpressure=False)
    src.start()
    s.start()
    deadline = time.monotonic() + 300
    while app.stats.messages < n_msgs and time.monotonic() < deadline:
        time.sleep(0.02)
    s.stop()
    src.stop()
    return app


def run() -> list[tuple[str, float, str]]:
    rows = []
    svc = PilotComputeService()

    n = 16
    app = StreamingKMeans(n_clusters=10, dim=3)
    _drain(
        svc, None,
        lambda c: KMeansClusterSource(c, SourceConfig("t", total_messages=n), points_per_msg=5000),
        app, n,
    )
    rows.append(("process_kmeans", app.stats.compute_time / max(app.stats.messages, 1) * 1e6,
                 f"msgs_per_s={app.stats.msgs_per_sec:.2f}"))
    svc.cancel()

    for alg, iters, n in (("gridrec", 0, 6), ("mlem", 4, 4)):
        svc = PilotComputeService()
        app = ReconstructionApp(alg, n=64, mlem_iters=iters or 4)
        _drain(
            svc, None,
            lambda c: LightsourceTemplateSource(
                c, SourceConfig("t", total_messages=n), n_angles=64, n_det=96),
            app, n, max_batch=1,
        )
        rows.append((f"process_{alg}", app.stats.compute_time / max(app.stats.messages, 1) * 1e6,
                     f"msgs_per_s={app.stats.msgs_per_sec:.2f}"))
        svc.cancel()
    return rows

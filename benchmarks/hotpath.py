"""Hot-path before/after benchmark: shape-bucketed dispatch + async
double-buffering vs the legacy one-compile-per-shape, block-every-batch path.

Each scenario runs the SAME message trace through a legacy-configured
processor (``bucketed/batched=False, async_depth=0``) and the overhauled one,
in one process, and emits ``BENCH_hotpath.json`` with msgs/sec, p50/p99
per-batch latency and compile counts for both — the repo's perf trajectory
(ISSUE 2; see docs/perf.md for how to read it).

    PYTHONPATH=src python -m benchmarks.hotpath [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import dataclass
from typing import Any

import numpy as np

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "BENCH_hotpath.json")


@dataclass
class Msg:
    """Broker-free stand-in for ``broker.consumer.Message`` — the benchmark
    measures the compute hot path, not broker transport."""

    value: Any
    timestamp: float = 0.0


def _stats_row(app, n_msgs: int, wall: float) -> dict:
    return {
        "msgs_per_sec": n_msgs / wall if wall > 0 else 0.0,
        "items_per_sec": app.stats.items / wall if wall > 0 else 0.0,
        "batch_latency_p50_s": app.stats.latency.p50,
        "batch_latency_p99_s": app.stats.latency.p99,
        "compiles": app.compiles,
        "wall_s": wall,
        "batches": app.stats.batches,
        "messages": app.stats.messages,
    }


def _drive(app, batches, warmup=()) -> dict:
    """Run ``warmup`` batches (compile coverage, excluded from stats), then
    time the trace. Scenarios where recompiles ARE the measured pathology
    (variable-rate kmeans) pass no warmup."""
    state = None
    for batch in warmup:
        state = app.process(state, batch)
    app.reset_stats()
    n_msgs = 0
    t0 = time.monotonic()
    for batch in batches:
        state = app.process(state, batch)
        n_msgs += len(batch)
    app.sync()
    return _stats_row(app, n_msgs, time.monotonic() - t0)


# ---------------------------------------------------------------------------
# scenario: variable-rate StreamingKMeans (the acceptance scenario)
# ---------------------------------------------------------------------------


def bench_kmeans(quick: bool) -> dict:
    from repro.miniapps import StreamingKMeans

    n_batches = 24 if quick else 72
    rng = np.random.default_rng(7)
    # variable-rate trace: every batch has a distinct point count, the
    # worst case for shape-specialized jit (one compile per batch)
    sizes = rng.integers(300, 3000 if quick else 6000, size=n_batches)
    batches = [[Msg(rng.normal(size=(int(n), 3)))] for n in sizes]

    def make(bucketed, depth):
        return StreamingKMeans(n_clusters=10, dim=3, seed=1,
                               bucketed=bucketed, async_depth=depth)

    before = _drive(make(False, 0), batches)
    after_app = make(True, 2)
    after = _drive(after_app, batches)
    return {
        "trace": {"batches": n_batches, "distinct_shapes": len(set(int(s) for s in sizes))},
        "bucket_count": len(after_app.buckets),
        "before": before,
        "after": after,
        "speedup_msgs_per_sec": after["msgs_per_sec"] / max(before["msgs_per_sec"], 1e-9),
    }


# ---------------------------------------------------------------------------
# scenario: GridRec micro-batches (per-message loop vs stacked vmap)
# ---------------------------------------------------------------------------


def bench_gridrec(quick: bool) -> dict:
    from repro.kernels.tomo import project_ref, shepp_logan
    from repro.miniapps import ReconstructionApp
    import jax.numpy as jnp

    n = 32 if quick else 64
    n_angles, n_det = (16, 48) if quick else (64, 96)
    img = shepp_logan(n)
    angles = jnp.linspace(0, jnp.pi, n_angles, endpoint=False)
    sino = np.asarray(project_ref(img, angles, n_det))
    rng = np.random.default_rng(3)
    n_batches = 12 if quick else 32
    # variable frames-per-batch: the legacy path loops (and re-materializes
    # angles) per message; the batched path stacks each group into one call
    batches = [[Msg(sino * (1.0 + 0.01 * j)) for j in range(int(rng.integers(1, 5)))]
               for _ in range(n_batches)]

    def make(batched, depth):
        return ReconstructionApp("gridrec", n=n, batched=batched, async_depth=depth)

    # fixed frame shape: both paths reach steady state after one compile per
    # bucket, so warm each bucket size once and measure steady state
    warmup = [[Msg(sino)] * k for k in (1, 2, 4)]
    before = _drive(make(False, 0), batches, warmup=warmup)
    after_app = make(True, 2)
    after = _drive(after_app, batches, warmup=warmup)
    return {
        "trace": {"batches": n_batches, "frames": sum(len(b) for b in batches)},
        "bucket_count": len(after_app.batch_buckets),
        "before": before,
        "after": after,
        "speedup_msgs_per_sec": after["msgs_per_sec"] / max(before["msgs_per_sec"], 1e-9),
    }


# ---------------------------------------------------------------------------
# scenario: LM serving (python decode loop vs fused lax.scan, full mode only)
# ---------------------------------------------------------------------------


def bench_lm_serve(quick: bool) -> dict | None:
    if quick:
        return None
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_arch
    from repro.miniapps import LMServeApp

    cfg = get_arch("smollm-135m").reduced(n_layers=2)
    prompt_len, gen_tokens, req_batch = 16, 8, 2
    app = LMServeApp(cfg, prompt_len=prompt_len, gen_tokens=gen_tokens,
                     batch=req_batch, async_depth=2)
    params = app.model.init(jax.random.key(0))
    rng = np.random.default_rng(11)
    batches = [[Msg(rng.integers(1, cfg.vocab_size, size=(req_batch, prompt_len)).astype(np.int32))
                for _ in range(3)] for _ in range(24)]

    # legacy baseline: per-message prefill + per-token python decode loop,
    # blocking per message (the pre-overhaul LMServeApp.process)
    prefill = jax.jit(app.model.prefill)
    decode = jax.jit(app.model.decode)

    def legacy(batches) -> dict:
        import time as _t

        n_msgs, items = 0, 0
        lat = []
        t0 = _t.monotonic()
        for batch in batches:
            tb = _t.monotonic()
            for m in batch:
                toks = jnp.asarray(m.value)
                logits, cache = prefill(params, {"tokens": toks})
                cache = jax.tree.map(
                    lambda c: jnp.pad(c, [(0, 0)] * 2 + [(0, gen_tokens)] + [(0, 0)] * (c.ndim - 3))
                    if c.ndim >= 4 else c, cache)
                pos = jnp.full((toks.shape[0],), prompt_len - 1, jnp.int32)
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                for _ in range(gen_tokens - 1):
                    pos = pos + 1
                    logits, cache = decode(params, cache, {"tokens": tok, "positions": pos})
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                tok.block_until_ready()
                items += toks.shape[0] * gen_tokens
            lat.append(_t.monotonic() - tb)
            n_msgs += len(batch)
        wall = _t.monotonic() - t0
        return {
            "msgs_per_sec": n_msgs / wall,
            "items_per_sec": items / wall,
            "batch_latency_p50_s": float(np.quantile(lat, 0.5)),
            "batch_latency_p99_s": float(np.quantile(lat, 0.99)),
            "compiles": -1,
            "wall_s": wall,
            "batches": len(batches),
            "messages": n_msgs,
        }

    legacy(batches[:1])  # warm the legacy jits (stats discarded)
    before = legacy(batches)

    state = app.process(params, batches[0])  # warm prefill/scan compiles
    app.reset_stats()
    n_msgs = 0
    t0 = time.monotonic()
    for batch in batches:
        state = app.process(state, batch)
        n_msgs += len(batch)
    app.sync()
    after = _stats_row(app, n_msgs, time.monotonic() - t0)
    return {
        "trace": {"batches": len(batches), "messages": n_msgs},
        "before": before,
        "after": after,
        "speedup_msgs_per_sec": after["msgs_per_sec"] / max(before["msgs_per_sec"], 1e-9),
    }


# ---------------------------------------------------------------------------


def bench_all(quick: bool, out_path: str = DEFAULT_OUT) -> dict:
    import jax

    scenarios = {"kmeans_variable_rate": bench_kmeans(quick),
                 "gridrec_microbatch": bench_gridrec(quick)}
    lm = bench_lm_serve(quick)
    if lm is not None:
        scenarios["lm_serve"] = lm
    report = {
        "meta": {
            "quick": quick,
            "backend": jax.default_backend(),
            "unix_time": time.time(),
        },
        "scenarios": scenarios,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def _rows(report: dict) -> list[tuple[str, float, str]]:
    rows = []
    for name, sc in report["scenarios"].items():
        after = sc["after"]
        rows.append((
            f"hotpath_{name}",
            after["batch_latency_p50_s"] * 1e6,
            f"msgs_per_s={after['msgs_per_sec']:.2f};speedup={sc['speedup_msgs_per_sec']:.2f}x"
            f";compiles={after['compiles']}",
        ))
    return rows


def run() -> list[tuple[str, float, str]]:
    """benchmarks.run entry point: quick mode, JSON emitted as side effect."""
    return _rows(bench_all(quick=True))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small shapes/traces (CI smoke)")
    ap.add_argument("--out", default=DEFAULT_OUT, help="JSON report path")
    args = ap.parse_args()
    report = bench_all(args.quick, args.out)
    for name, us, derived in _rows(report):
        print(f"{name},{us:.1f},{derived}")
    for name, sc in report["scenarios"].items():
        print(f"  {name}: {sc['before']['msgs_per_sec']:.2f} -> {sc['after']['msgs_per_sec']:.2f} msgs/s "
              f"({sc['speedup_msgs_per_sec']:.2f}x), compiles {sc['before']['compiles']} -> {sc['after']['compiles']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
